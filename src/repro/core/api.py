"""User-facing distributed dataframe API (paper §2.1, Fig. 2b).

``DDF`` is the *virtual* collection of row partitions: users write
single-partition-style programs; the runtime decides local vs distributed
execution from operator semantics (paper Fig. 1). Globally a DDF is a set of
device-sharded columns of shape (P*capacity, ...) plus per-partition valid
counts (P,), laid out over the mesh's row-partition axes.

Each method wraps the corresponding in-shard_map operator from
``operators.py`` under jit (compiled callables are cached per (context,
operator, schema, static-params) so steady-state calls don't re-trace).
Planning (quota/capacity/strategy) is host-side via ``patterns.py``.

Auxiliary outputs (overflow counters, pivots, ...) come back with a leading
per-worker axis of size P.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import threading
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import cost_model, operators, patterns
from .vocab import DictVocab, encode_strings, is_string_array
from .. import expr as _expr
from ..compat import shard_map
from ..obs import trace as _trace
from .comm.communicator import Communicator, make_communicator
from .dataframe import Table
from .local_ops import select as local_select
from .local_ops import with_column as local_with_column
from .partition import default_quota

__all__ = ["DDFContext", "DDF"]


class _LRUCache:
    """Bounded least-recently-used cache for compiled operators/plans.

    The previous unbounded dict keyed on ``id(mesh)`` could (a) grow without
    limit across contexts and (b) alias entries when a garbage-collected
    mesh's id was reused; this keys on stable signatures (see
    :func:`mesh_signature`) and evicts the least recently used entry past
    ``maxsize``.

    Thread-safe: concurrent queries multiplexed by ``repro.service`` share
    the process-wide plan/op caches, so get/put (including the recency
    reordering and eviction, which mutate the OrderedDict) run under a
    lock. Hit/miss/eviction counts are tracked for the service's cache
    telemetry (:meth:`stats`).
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._d: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key):
        with self._lock:
            try:
                self._d.move_to_end(key)
                val = self._d[key]
            except KeyError:
                self.misses += 1
                return None
            self.hits += 1
            return val

    def put(self, key, value):
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)
                self.evictions += 1

    def stats(self) -> dict:
        """Telemetry snapshot: ``{hits, misses, evictions, size, maxsize}``."""
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "size": len(self._d),
                    "maxsize": self.maxsize}

    def __len__(self):
        with self._lock:
            return len(self._d)


@functools.lru_cache(maxsize=32)
def mesh_signature(mesh: Mesh) -> tuple:
    """Stable identity for a mesh: axis names + shape + device ids.

    Unlike ``id(mesh)``, this survives garbage collection (ids can be
    reused) and treats equal meshes as equal, so cache entries are neither
    aliased nor duplicated. Memoized so the O(n_devices) tuple is not
    rebuilt on every operator dispatch."""
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


_OP_CACHE = _LRUCache(maxsize=256)


def cached_op(ctx: "DDFContext", key: tuple, fn: Callable, arg_schemas: tuple) -> Callable:
    """Fetch-or-compile the jitted shard_map for (context, op key, schemas).

    Shared by the eager ``DDF._run`` path and the lazy plan executor, so a
    lazy pipeline whose final stage matches an eager op reuses the same
    compiled callable. The key includes the kernel-dispatch signature
    (``repro.kernels.registry``): hot-path kernel routing is decided at
    trace time, so a compiled program built under one backend override
    must never serve another."""
    from ..kernels import registry as _kernel_registry

    cache_key = (mesh_signature(ctx.mesh), ctx.axes, key, arg_schemas,
                 _kernel_registry.dispatch_signature())
    op = _OP_CACHE.get(cache_key)
    if op is None:
        # compile misses are the expensive rare path — span them so traces
        # separate trace/compile stalls from steady-state dispatches
        with _trace.span("core.compile", op=str(key[0])):
            op = _build_op(ctx, fn, arg_schemas)
        _OP_CACHE.put(cache_key, op)
    return op


@dataclasses.dataclass(frozen=True)
class DDFContext:
    """Execution environment: mesh + row-partition axes (paper's `env`)."""

    mesh: Mesh
    axes: tuple[str, ...] = ("data",)
    fabric: str = "ici"

    @property
    def nworkers(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.axes]))

    @property
    def axis(self):
        return self.axes if len(self.axes) > 1 else self.axes[0]

    def comm(self) -> Communicator:
        return make_communicator(self.axis, self.fabric)

    def row_spec(self) -> P:
        return P(self.axes)

    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.row_spec())


def _schema_sig(ddf: "DDF") -> tuple:
    return tuple((k, str(v.dtype), v.shape) for k, v in sorted(ddf.columns.items()))


def callable_signature(fn: Callable) -> tuple:
    """Best-effort stable identity for a user callable (predicate/map fn):
    code location + bytecode hash + hashable default/closure values.

    Cache keys for select/map ops include this alongside the user-supplied
    name, so two different lambdas (even same-line ones differing only in a
    captured constant) do not silently alias a compiled operator."""
    code = getattr(fn, "__code__", None)
    if code is None:
        return (repr(fn),)

    def ident(v):
        # keep the raw value when hashable: cache-key comparison then uses
        # __eq__, so hash-equal-but-unequal values (hash(-1)==hash(-2))
        # never alias; unhashable values fall back to object identity,
        # which the cache entry itself keeps alive.
        try:
            hash(v)
            return v
        except TypeError:
            return id(v)

    cells = tuple(ident(c.cell_contents)
                  for c in (getattr(fn, "__closure__", None) or ()))
    defaults = tuple(ident(v) for v in (getattr(fn, "__defaults__", None) or ()))
    # co_consts/co_names distinguish same-line lambdas that differ only in a
    # literal or a referenced column name (identical co_code).
    consts = tuple(ident(v) for v in code.co_consts)
    return (code.co_filename, code.co_firstlineno, hash(code.co_code),
            code.co_names, consts, defaults, cells)


def _build_op(ctx: DDFContext, fn: Callable, arg_schemas: tuple) -> Callable:
    """Compile ``fn(comm, *local_tables) -> Table | (Table|aux, ...)`` into a
    jitted shard_map over the context's row-partition axes."""
    spec = P(ctx.axes)
    nw = ctx.nworkers

    def wrapper(*flat):
        locs = []
        for i in range(0, len(flat), 2):
            cols, cnt = flat[i], flat[i + 1]
            locs.append(Table(dict(cols), cnt.reshape(())))
        res = fn(ctx.comm(), *locs)
        if not isinstance(res, tuple):
            res = (res,)
        out = []
        for r in res:
            if isinstance(r, Table):
                out.append((dict(r.columns), r.nvalid.reshape((1,))))
            else:
                # aux pytree: add a leading per-worker axis
                out.append(jax.tree.map(lambda x: jnp.asarray(x)[None, ...], r))
        return tuple(out)

    in_specs = []
    for schema in arg_schemas:
        in_specs.append({k: spec for k, _, _ in schema})
        in_specs.append(spec)
    # Every output leaf carries a leading per-worker axis (table columns have
    # their capacity dim; nvalid is reshaped (1,); aux leaves get [None]), so
    # a single prefix spec shards the whole output pytree.
    sm = shard_map(wrapper, mesh=ctx.mesh, in_specs=tuple(in_specs),
                   out_specs=spec, check_vma=False)
    return jax.jit(sm)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DDF:
    """Distributed dataframe: global columns (P*cap, ...) + counts (P,)."""

    columns: dict[str, jax.Array]
    counts: jax.Array  # (P,) int32 — valid rows per partition
    ctx: DDFContext
    #: host-side vocabularies of dict-encoded string columns (name ->
    #: ``DictVocab``); the device column holds int32 codes. Rides in the
    #: pytree aux data (DictVocab is hashable) so jit caching keys on it.
    vocabs: dict = dataclasses.field(default_factory=dict)
    # host-side caches (not pytree children): global row count + lazy handle
    _nrows: int | None = dataclasses.field(default=None, repr=False, compare=False)
    _lazy_cache: object = dataclasses.field(default=None, repr=False, compare=False)

    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return (tuple(self.columns[n] for n in names) + (self.counts,),
                (names, self.ctx, tuple(sorted(self.vocabs.items()))))

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, ctx, vocabs = aux
        *cols, counts = children
        return cls(dict(zip(names, cols)), counts, ctx, dict(vocabs))

    # -- metadata --------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0] // self.ctx.nworkers

    @property
    def column_names(self):
        return tuple(sorted(self.columns))

    def num_rows(self) -> int:
        """Global live-row count (device->host sync; cached per instance)."""
        if self._nrows is None:
            self._nrows = int(np.sum(np.asarray(self.counts)))
        return self._nrows

    # -- construction ------------------------------------------------------------
    @classmethod
    def from_numpy(cls, data: Mapping[str, np.ndarray], ctx: DDFContext,
                   capacity: int | None = None, mode: str | None = None):
        """Partitioned input: rows split contiguously across workers
        (paper §5.3.8 partitioned I/O).

        ``mode`` selects the API flavor: "eager" returns a ``DDF`` whose
        methods execute immediately (today's semantics); "lazy" returns a
        ``repro.plan.LazyDDF`` that builds a logical plan and executes on
        ``.collect()``. None consults ``repro.plan.get_default_mode()``."""
        nw = ctx.nworkers
        n = len(next(iter(data.values())))
        per = -(-n // nw)
        cap = per if capacity is None else capacity
        cols = {}
        vocabs = {}
        for k, v in data.items():
            v = np.asarray(v)
            if is_string_array(v):  # dict-encode: int32 codes + host vocab
                v, vocabs[k] = encode_strings(v)
            buf = np.zeros((nw, cap) + v.shape[1:], v.dtype)
            for w in range(nw):
                chunk = v[w * per: (w + 1) * per][:cap]
                buf[w, : len(chunk)] = chunk
            cols[k] = jax.device_put(buf.reshape((nw * cap,) + v.shape[1:]), ctx.sharding())
        counts = np.minimum(np.maximum(n - per * np.arange(nw), 0), min(per, cap)).astype(np.int32)
        ddf = cls(cols, jax.device_put(counts, ctx.sharding()), ctx, vocabs)
        if mode is None:
            from .. import plan  # local import: plan depends on this module
            mode = plan.get_default_mode()
        return ddf.lazy() if mode == "lazy" else ddf

    def to_numpy(self) -> dict[str, np.ndarray]:
        """Gather live rows to host, in partition order. Dict-encoded
        columns come back decoded (numpy string arrays, not codes)."""
        counts = np.asarray(self.counts)
        cap = self.capacity
        out = {}
        for k, v in self.columns.items():
            v = np.asarray(v).reshape((self.ctx.nworkers, cap) + v.shape[1:])
            g = np.concatenate([v[w, : counts[w]] for w in range(self.ctx.nworkers)])
            out[k] = self.vocabs[k].decode(g) if k in self.vocabs else g
        return out

    # -- execution plumbing ---------------------------------------------------------
    def _run(self, key: tuple, fn, *ddfs: "DDF"):
        schemas = tuple(_schema_sig(d) for d in (self,) + ddfs)
        op = cached_op(self.ctx, key, fn, schemas)
        flat = []
        for d in (self,) + ddfs:
            flat.append(d.columns)
            flat.append(d.counts)
        results = op(*flat)
        out = []
        for item in results:
            if isinstance(item, tuple) and len(item) == 2 and isinstance(item[0], dict) and not isinstance(item[1], dict):
                out.append(DDF(item[0], item[1], self.ctx))
            else:
                out.append(item)
        return out[0] if len(out) == 1 else tuple(out)

    # -- dict-encoded string columns (vocab plumbing) ---------------------------
    def _attach(self, res, vocabs: Mapping[str, DictVocab]):
        """Attach vocab metadata to the DDF element(s) of an op result,
        restricted to columns the result actually has."""
        items = res if isinstance(res, tuple) else (res,)
        for item in items:
            if isinstance(item, DDF):
                item.vocabs = {n: v for n, v in vocabs.items()
                               if n in item.columns}
        return res

    def _recode(self, mappings: Mapping[str, np.ndarray]) -> "DDF":
        """Apply per-column int32 gather maps — the device half of vocab
        unification (``new_codes = map[old_codes]``). The op cache keys on
        the map *contents*, so two recodes into different merged vocabs
        never alias one compiled program."""
        maps = {n: np.asarray(m, dtype=np.int32) for n, m in mappings.items()
                if n in self.columns}
        if not maps:
            # shallow copy: callers overwrite .vocabs on the result, and
            # mutating self would corrupt the input relation's metadata
            return DDF(dict(self.columns), self.counts, self.ctx,
                       dict(self.vocabs))
        key = ("recode", tuple(sorted((n, m.tobytes()) for n, m in maps.items())))

        def fn(comm, t):
            cols = dict(t.columns)
            for n, m in maps.items():
                cols[n] = jnp.asarray(m)[cols[n]]
            return Table(cols, t.nvalid)

        return self._run(key, fn)

    def _unify_vocabs_with(self, other: "DDF", op: str):
        """Vocab unification at a binary boundary (join/union/difference):
        merge each shared dict column's vocabs host-side and recode both
        sides into the merged code space. Returns ``(left, right, merged)``
        where merged covers every dict column of either side."""
        mixed = sorted(n for n in set(self.vocabs) ^ set(other.vocabs)
                       if n in self.columns and n in other.columns)
        if mixed:
            raise TypeError(
                f"{op}: column(s) {mixed} are dict-encoded strings on one "
                f"side but plain numerics on the other — codes and raw "
                f"values are not comparable; encode both sides or neither")
        merged = {**other.vocabs, **self.vocabs}
        lmaps, rmaps = {}, {}
        for n in sorted(set(self.vocabs) & set(other.vocabs)):
            lv, rv = self.vocabs[n], other.vocabs[n]
            if lv.words == rv.words:
                continue
            mv = lv.merge(rv)
            merged[n] = mv
            if not lv.is_identity_into(mv):
                lmaps[n] = lv.recode_map(mv)
            if not rv.is_identity_into(mv):
                rmaps[n] = rv.recode_map(mv)
        left, right = self._recode(lmaps), other._recode(rmaps)
        left.vocabs = {n: merged[n] for n in self.vocabs}
        right.vocabs = {n: merged[n] for n in other.vocabs}
        return left, right, merged

    # -- embarrassingly parallel (paper §5.3.1) ----------------------------------
    def select(self, pred, name: str = "pred") -> "DDF":
        """Filter rows by a boolean expression: ``select(col("a") > 3)``.

        Expressions (``repro.expr``) are validated against the schema at
        call time (unknown columns raise ``KeyError`` listing the schema),
        constant-folded, compiled to a pure jax function, and cache-keyed
        by their structural hash. Passing a Python callable over the column
        dict is deprecated (one-shot ``DeprecationWarning``) but keeps
        bit-identical behavior through the legacy fingerprint path."""
        if isinstance(pred, (_expr.Expr, bool)) or _expr.is_when_builder(pred):
            pred = _expr.prepare_row_expr(pred, self.columns, "select",
                                          vocabs=self.vocabs or None)
            fn = _expr.to_jax_fn(pred)
            return self._attach(self._run(("select", name, pred),
                                          lambda comm, t: local_select(t, fn)),
                                self.vocabs)
        _expr.warn_callable_deprecated("select")
        return self._attach(self._run(("select", name, callable_signature(pred)),
                                      lambda comm, t: local_select(t, pred)),
                            self.vocabs)

    def with_column(self, name: str, value) -> "DDF":
        """Add (or overwrite) column ``name`` from an expression:
        ``with_column("c", col("a") + col("b"))``. Scalars are coerced to
        literals; all other columns pass through unchanged. The expression
        is validated against the schema (``KeyError`` listing the schema on
        unknown references) and compiled to a pure jax function."""
        e = _expr.prepare_row_expr(value, self.columns, "with_column",
                                   vocabs=self.vocabs or None)
        fn = _expr.to_jax_fn(e)
        return self._attach(
            self._run(("with_column", name, e),
                      lambda comm, t: local_with_column(t, name, fn)),
            {n: v for n, v in self.vocabs.items() if n != name})

    def _check_columns(self, names: Sequence[str], op: str) -> None:
        missing = [n for n in names if n not in self.columns]
        if missing:
            raise KeyError(
                f"{op}: unknown column(s) {missing}; "
                f"available schema: {sorted(self.columns)}")

    def project(self, names: Sequence[str]) -> "DDF":
        """Column projection (zero-copy). Unknown names raise ``KeyError``
        listing the available schema instead of failing inside jit."""
        self._check_columns(names, "project")
        return DDF({n: self.columns[n] for n in names}, self.counts, self.ctx,
                   {n: v for n, v in self.vocabs.items() if n in names})

    def drop(self, names: Sequence[str]) -> "DDF":
        """Drop columns — the natural inverse of :meth:`project`."""
        names = tuple(names)
        self._check_columns(names, "drop")
        gone = set(names)
        return DDF({k: v for k, v in self.columns.items() if k not in gone},
                   self.counts, self.ctx,
                   {k: v for k, v in self.vocabs.items() if k not in gone})

    def rename(self, mapping: Mapping[str, str]) -> "DDF":
        """Column rename (paper Fig. 6 Modin-algebra surface; zero-copy).
        Unknown source names raise ``KeyError``; colliding target names
        raise ``ValueError`` (a silent dict overwrite would drop a column)."""
        self._check_columns(tuple(mapping), "rename")
        targets = [mapping.get(k, k) for k in self.columns]
        dup = {t for t in targets if targets.count(t) > 1}
        if dup:
            raise ValueError(f"rename: duplicate target column(s) {sorted(dup)}")
        return DDF({mapping.get(k, k): v for k, v in self.columns.items()},
                   self.counts, self.ctx,
                   {mapping.get(k, k): v for k, v in self.vocabs.items()})

    def map_columns(self, fn, name: str = "map") -> "DDF":
        """Legacy column-wise map over the raw column dict (deprecated —
        one-shot ``DeprecationWarning``; use expression-based
        :meth:`with_column` / :meth:`project` instead, which the optimizer
        can analyze). Behavior is unchanged: bit-identical results through
        the callable-fingerprint cache path."""
        _expr.warn_callable_deprecated("map_columns")
        return self._run(("map", name, callable_signature(fn)),
                         lambda comm, t: Table(dict(fn(t.columns)), t.nvalid))

    # -- loosely synchronous ----------------------------------------------------
    def join(self, other: "DDF", on: Sequence[str], strategy: str = "auto",
             quota: int | None = None, capacity: int | None = None,
             num_chunks: int | None = None):
        """Equi-join. ``strategy="auto"`` lets the planner pick hash-shuffle
        vs broadcast AND the shuffle pipeline depth from the cost model;
        ``num_chunks`` overrides the depth (1 = monolithic all-to-all)."""
        on = tuple(on)
        left, right, merged = self._unify_vocabs_with(other, "join")
        nw = self.ctx.nworkers
        if strategy == "auto":
            plan = patterns.plan_join(
                left.num_rows(), right.num_rows(), nw, left.capacity,
                params=cost_model.params_for_fabric(self.ctx.fabric))
            strategy = plan.strategy
            if num_chunks is None:
                num_chunks = plan.num_chunks
        num_chunks = num_chunks or 1
        quota = quota or default_quota(left.capacity, nw)
        capacity = capacity or 2 * left.capacity
        if strategy == "broadcast":
            # replicate the small side; left/right column roles are preserved
            # either way (matches the lazy planner's broadcast_left/right)
            gather = "left" if left.num_rows() <= right.num_rows() else "right"
            return self._attach(
                left._run(("bjoin", on, capacity, gather),
                          lambda comm, l, r: operators.dist_join_broadcast(
                              comm, l, r, on, capacity, gather=gather),
                          right),
                merged)
        return self._attach(
            left._run(("join", on, quota, capacity, num_chunks),
                      lambda comm, l, r: operators.dist_join_shuffle(
                          comm, l, r, on, quota, capacity, num_chunks=num_chunks),
                      right),
            merged)

    def groupby(self, by: Sequence[str], aggs,
                pre_combine: bool | None = None, cardinality_hint: float | None = None,
                quota: int | None = None, capacity: int | None = None,
                num_chunks: int | None = None):
        """GroupBy-aggregate. ``aggs`` is either the canonical mapping
        ``{value_col: (op, ...)}`` or a sequence of aggregation expressions
        (``[col("v").sum(), col("v").mean().alias("avg")]`` — aliases apply
        as a zero-copy rename on the result). With ``pre_combine=None`` the
        planner picks combine-shuffle-reduce vs plain shuffle (from
        ``cardinality_hint``) and the shuffle pipeline depth from table
        sizes. A pinned ``pre_combine`` skips planning entirely (no
        device->host row-count sync) and defaults to the monolithic shuffle
        — pass ``num_chunks`` explicitly to pipeline on that path."""
        by = tuple(by)
        renames: tuple = ()
        if not isinstance(aggs, Mapping):
            aggs, renames = _expr.parse_agg_specs(aggs)
        aggs = {k: tuple(v) for k, v in aggs.items()}
        self._check_columns(sorted(aggs), "groupby(aggs)")
        bad = sorted(f"{c}.{o}" for c, ops_ in aggs.items() for o in ops_
                     if c in self.vocabs and o in ("sum", "mean"))
        if bad:
            raise TypeError(
                f"groupby: aggregation(s) {bad} are arithmetic over a "
                f"dict-encoded string column — codes have order but no "
                f"arithmetic; only min/max/count apply to strings")
        out_vocabs = dict(self.vocabs)
        for c, ops_ in aggs.items():
            if c in self.vocabs:  # ordered aggs of a dict column stay dict
                for o in ops_:
                    if o in ("min", "max"):
                        out_vocabs[f"{c}_{o}"] = self.vocabs[c]
        nw = self.ctx.nworkers
        if pre_combine is None:
            # planning reads row counts (a blocking device->host sync), so it
            # only runs when the caller left the strategy to the planner.
            card = cardinality_hint if cardinality_hint is not None else 0.0
            plan = patterns.plan_groupby(
                card, nw, capacity or self.capacity, n_rows=self.num_rows(),
                params=cost_model.params_for_fabric(self.ctx.fabric))
            pre_combine = plan.strategy == "combine_shuffle_reduce"
            if num_chunks is None:
                num_chunks = plan.num_chunks
        num_chunks = num_chunks or 1
        quota = quota or default_quota(self.capacity, nw)
        capacity = capacity or self.capacity
        key = ("groupby", by, tuple(sorted(aggs.items())), pre_combine, quota,
               capacity, num_chunks)
        res = self._attach(
            self._run(key, lambda comm, t: operators.dist_groupby(
                comm, t, by, aggs, quota, capacity, pre_combine,
                num_chunks=num_chunks)),
            out_vocabs)
        if renames:
            res = (res[0].rename(dict(renames)),) + tuple(res[1:])
        return res

    def unique(self, subset: Sequence[str], quota: int | None = None, capacity: int | None = None,
               num_chunks: int = 1):
        """Distinct rows by ``subset`` key columns (combine-shuffle-reduce)."""
        subset = tuple(subset)
        nw = self.ctx.nworkers
        quota = quota or default_quota(self.capacity, nw)
        capacity = capacity or self.capacity
        return self._attach(
            self._run(("unique", subset, quota, capacity, num_chunks),
                      lambda comm, t: operators.dist_unique(
                          comm, t, subset, quota, capacity, num_chunks=num_chunks)),
            self.vocabs)

    def union(self, other: "DDF", on: Sequence[str], quota: int | None = None,
              capacity: int | None = None, num_chunks: int = 1):
        """Set union by key (concat + distributed unique, paper Table 2)."""
        on = tuple(on)
        left, right, merged = self._unify_vocabs_with(other, "union")
        nw = self.ctx.nworkers
        cap = left.capacity + right.capacity
        quota = quota or default_quota(cap, nw)
        capacity = capacity or cap
        return self._attach(
            left._run(("union", on, quota, capacity, num_chunks),
                      lambda comm, l, r: operators.dist_union(
                          comm, l, r, on, quota, capacity, num_chunks=num_chunks),
                      right),
            merged)

    def difference(self, other: "DDF", on: Sequence[str], quota: int | None = None,
                   capacity: int | None = None, num_chunks: int = 1):
        """Set difference by key (co-partition + local anti-join)."""
        on = tuple(on)
        left, right, merged = self._unify_vocabs_with(other, "difference")
        nw = self.ctx.nworkers
        quota = quota or default_quota(left.capacity, nw)
        capacity = capacity or left.capacity
        return self._attach(
            left._run(("difference", on, quota, capacity, num_chunks),
                      lambda comm, l, r: operators.dist_difference(
                          comm, l, r, on, quota, capacity, num_chunks=num_chunks),
                      right),
            merged)

    def sort_values(self, by: str, descending: bool = False, quota: int | None = None,
                    capacity: int | None = None, num_chunks: int = 1):
        """Global sample sort by ``by``; partition i gets the i-th key range.
        ``num_chunks`` > 1 pipelines the range shuffle against the merge."""
        nw = self.ctx.nworkers
        quota = quota or default_quota(self.capacity, nw, safety=3.0)
        capacity = capacity or 2 * self.capacity
        return self._attach(
            self._run(("sort", by, descending, quota, capacity, num_chunks),
                      lambda comm, t: operators.dist_sort(
                          comm, t, by, quota, capacity, descending=descending,
                          num_chunks=num_chunks)),
            self.vocabs)

    def agg(self, column: str, op: str):
        if column in self.vocabs and op not in ("min", "max", "count"):
            raise TypeError(
                f"agg: {op!r} over dict-encoded string column {column!r} — "
                f"codes have order but no arithmetic; only min/max/count "
                f"apply to strings")
        out = self._run(("agg", column, op),
                        lambda comm, t: (operators.dist_column_agg(comm, t, column, op),))
        val = np.asarray(out)[0]  # replicated; take worker 0's copy
        if column in self.vocabs and op in ("min", "max"):
            return self.vocabs[column].words[int(val)]  # decode the scalar
        return val

    def length(self) -> int:
        out = self._run(("length",), lambda comm, t: (operators.dist_length(comm, t),))
        return int(np.asarray(out)[0])

    def rolling_sum(self, column: str, window: int):
        return self._run(("rolling", column, window),
                         lambda comm, t: operators.dist_window_sum(comm, t, column, window))

    def rolling(self, column: str, window: int, op: str = "sum"):
        """Rolling window aggregate: sum | mean | min | max (halo exchange)."""
        return self._run(("rollagg", column, window, op),
                         lambda comm, t: operators.dist_window_agg(comm, t, column, window, op))

    def transpose(self) -> "DDF":
        """Distributed transpose (gather-based; for matrix-shaped tables)."""
        return self._run(("transpose", self.capacity),
                         lambda comm, t: operators.dist_transpose(comm, t))

    def rebalance(self, quota: int | None = None, num_chunks: int = 1):
        """Evenly redistribute rows across workers, preserving global order."""
        quota = quota or self.capacity
        return self._attach(
            self._run(("rebalance", quota, num_chunks),
                      lambda comm, t: operators.rebalance(
                          comm, t, quota, num_chunks=num_chunks)),
            self.vocabs)

    def head(self, k: int) -> "DDF":
        return self._attach(
            self._run(("head", k), lambda comm, t: operators.dist_head(comm, t, k)),
            self.vocabs)

    # -- lazy plan layer (repro.plan) -------------------------------------------
    def lazy(self):
        """Lazy handle over this DDF: a ``repro.plan.LazyDDF`` whose operator
        methods build a logical plan; ``.collect()`` optimizes and executes
        the whole pipeline in one compiled program. Cached per instance so
        rebuilding a pipeline from the same DDF reuses plan/op caches."""
        if self._lazy_cache is None:
            from ..plan.frame import LazyDDF
            self._lazy_cache = LazyDDF.from_ddf(self)
        return self._lazy_cache

    def eager(self) -> "DDF":
        """This DDF itself — the eager escape hatch mirrors
        ``LazyDDF.eager()`` so either handle can be normalized."""
        return self
