"""User-facing distributed dataframe API (paper §2.1, Fig. 2b).

``DDF`` is the *virtual* collection of row partitions: users write
single-partition-style programs; the runtime decides local vs distributed
execution from operator semantics (paper Fig. 1). Globally a DDF is a set of
device-sharded columns of shape (P*capacity, ...) plus per-partition valid
counts (P,), laid out over the mesh's row-partition axes.

Each method wraps the corresponding in-shard_map operator from
``operators.py`` under jit (compiled callables are cached per (context,
operator, schema, static-params) so steady-state calls don't re-trace).
Planning (quota/capacity/strategy) is host-side via ``patterns.py``.

Auxiliary outputs (overflow counters, pivots, ...) come back with a leading
per-worker axis of size P.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import operators, patterns
from ..compat import shard_map
from .comm.communicator import Communicator, make_communicator
from .dataframe import Table
from .local_ops import select as local_select
from .partition import default_quota

__all__ = ["DDFContext", "DDF"]

_OP_CACHE: dict = {}


@dataclasses.dataclass(frozen=True)
class DDFContext:
    """Execution environment: mesh + row-partition axes (paper's `env`)."""

    mesh: Mesh
    axes: tuple[str, ...] = ("data",)
    fabric: str = "ici"

    @property
    def nworkers(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.axes]))

    @property
    def axis(self):
        return self.axes if len(self.axes) > 1 else self.axes[0]

    def comm(self) -> Communicator:
        return make_communicator(self.axis, self.fabric)

    def row_spec(self) -> P:
        return P(self.axes)

    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.row_spec())


def _schema_sig(ddf: "DDF") -> tuple:
    return tuple((k, str(v.dtype), v.shape) for k, v in sorted(ddf.columns.items()))


def _build_op(ctx: DDFContext, fn: Callable, arg_schemas: tuple) -> Callable:
    """Compile ``fn(comm, *local_tables) -> Table | (Table|aux, ...)`` into a
    jitted shard_map over the context's row-partition axes."""
    spec = P(ctx.axes)
    nw = ctx.nworkers

    def wrapper(*flat):
        locs = []
        for i in range(0, len(flat), 2):
            cols, cnt = flat[i], flat[i + 1]
            locs.append(Table(dict(cols), cnt.reshape(())))
        res = fn(ctx.comm(), *locs)
        if not isinstance(res, tuple):
            res = (res,)
        out = []
        for r in res:
            if isinstance(r, Table):
                out.append((dict(r.columns), r.nvalid.reshape((1,))))
            else:
                # aux pytree: add a leading per-worker axis
                out.append(jax.tree.map(lambda x: jnp.asarray(x)[None, ...], r))
        return tuple(out)

    in_specs = []
    for schema in arg_schemas:
        in_specs.append({k: spec for k, _, _ in schema})
        in_specs.append(spec)
    # Every output leaf carries a leading per-worker axis (table columns have
    # their capacity dim; nvalid is reshaped (1,); aux leaves get [None]), so
    # a single prefix spec shards the whole output pytree.
    sm = shard_map(wrapper, mesh=ctx.mesh, in_specs=tuple(in_specs),
                   out_specs=spec, check_vma=False)
    return jax.jit(sm)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DDF:
    """Distributed dataframe: global columns (P*cap, ...) + counts (P,)."""

    columns: dict[str, jax.Array]
    counts: jax.Array  # (P,) int32 — valid rows per partition
    ctx: DDFContext

    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names) + (self.counts,), (names, self.ctx)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, ctx = aux
        *cols, counts = children
        return cls(dict(zip(names, cols)), counts, ctx)

    # -- metadata --------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0] // self.ctx.nworkers

    @property
    def column_names(self):
        return tuple(sorted(self.columns))

    def num_rows(self) -> int:
        return int(np.sum(np.asarray(self.counts)))

    # -- construction ------------------------------------------------------------
    @classmethod
    def from_numpy(cls, data: Mapping[str, np.ndarray], ctx: DDFContext,
                   capacity: int | None = None) -> "DDF":
        """Partitioned input: rows split contiguously across workers
        (paper §5.3.8 partitioned I/O)."""
        nw = ctx.nworkers
        n = len(next(iter(data.values())))
        per = -(-n // nw)
        cap = per if capacity is None else capacity
        cols = {}
        for k, v in data.items():
            v = np.asarray(v)
            buf = np.zeros((nw, cap) + v.shape[1:], v.dtype)
            for w in range(nw):
                chunk = v[w * per: (w + 1) * per][:cap]
                buf[w, : len(chunk)] = chunk
            cols[k] = jax.device_put(buf.reshape((nw * cap,) + v.shape[1:]), ctx.sharding())
        counts = np.minimum(np.maximum(n - per * np.arange(nw), 0), min(per, cap)).astype(np.int32)
        return cls(cols, jax.device_put(counts, ctx.sharding()), ctx)

    def to_numpy(self) -> dict[str, np.ndarray]:
        """Gather live rows to host, in partition order."""
        counts = np.asarray(self.counts)
        cap = self.capacity
        out = {}
        for k, v in self.columns.items():
            v = np.asarray(v).reshape((self.ctx.nworkers, cap) + v.shape[1:])
            out[k] = np.concatenate([v[w, : counts[w]] for w in range(self.ctx.nworkers)])
        return out

    # -- execution plumbing ---------------------------------------------------------
    def _run(self, key: tuple, fn, *ddfs: "DDF"):
        schemas = tuple(_schema_sig(d) for d in (self,) + ddfs)
        cache_key = (id(self.ctx.mesh), self.ctx.axes, key, schemas)
        op = _OP_CACHE.get(cache_key)
        if op is None:
            op = _build_op(self.ctx, fn, schemas)
            _OP_CACHE[cache_key] = op
        flat = []
        for d in (self,) + ddfs:
            flat.append(d.columns)
            flat.append(d.counts)
        results = op(*flat)
        out = []
        for item in results:
            if isinstance(item, tuple) and len(item) == 2 and isinstance(item[0], dict) and not isinstance(item[1], dict):
                out.append(DDF(item[0], item[1], self.ctx))
            else:
                out.append(item)
        return out[0] if len(out) == 1 else tuple(out)

    # -- embarrassingly parallel (paper §5.3.1) ----------------------------------
    def select(self, pred, name: str = "pred") -> "DDF":
        return self._run(("select", name), lambda comm, t: local_select(t, pred))

    def project(self, names: Sequence[str]) -> "DDF":
        return DDF({n: self.columns[n] for n in names}, self.counts, self.ctx)

    def rename(self, mapping: Mapping[str, str]) -> "DDF":
        """Column rename (paper Fig. 6 Modin-algebra surface; zero-copy)."""
        return DDF({mapping.get(k, k): v for k, v in self.columns.items()},
                   self.counts, self.ctx)

    def map_columns(self, fn, name: str = "map") -> "DDF":
        return self._run(("map", name), lambda comm, t: Table(dict(fn(t.columns)), t.nvalid))

    # -- loosely synchronous ----------------------------------------------------
    def join(self, other: "DDF", on: Sequence[str], strategy: str = "auto",
             quota: int | None = None, capacity: int | None = None,
             num_chunks: int | None = None):
        """Equi-join. ``strategy="auto"`` lets the planner pick hash-shuffle
        vs broadcast AND the shuffle pipeline depth from the cost model;
        ``num_chunks`` overrides the depth (1 = monolithic all-to-all)."""
        on = tuple(on)
        nw = self.ctx.nworkers
        if strategy == "auto":
            plan = patterns.plan_join(self.num_rows(), other.num_rows(), nw, self.capacity)
            strategy = plan.strategy
            if num_chunks is None:
                num_chunks = plan.num_chunks
        num_chunks = num_chunks or 1
        quota = quota or default_quota(self.capacity, nw)
        capacity = capacity or 2 * self.capacity
        if strategy == "broadcast":
            small, big = (self, other) if self.num_rows() <= other.num_rows() else (other, self)
            return big._run(("bjoin", on, capacity),
                            lambda comm, b, s: operators.dist_join_broadcast(comm, b, s, on, capacity),
                            small)
        return self._run(("join", on, quota, capacity, num_chunks),
                         lambda comm, l, r: operators.dist_join_shuffle(
                             comm, l, r, on, quota, capacity, num_chunks=num_chunks),
                         other)

    def groupby(self, by: Sequence[str], aggs: Mapping[str, Sequence[str]],
                pre_combine: bool | None = None, cardinality_hint: float | None = None,
                quota: int | None = None, capacity: int | None = None,
                num_chunks: int | None = None):
        """GroupBy-aggregate. With ``pre_combine=None`` the planner picks
        combine-shuffle-reduce vs plain shuffle (from ``cardinality_hint``)
        and the shuffle pipeline depth from table sizes. A pinned
        ``pre_combine`` skips planning entirely (no device->host row-count
        sync) and defaults to the monolithic shuffle — pass ``num_chunks``
        explicitly to pipeline on that path."""
        by = tuple(by)
        aggs = {k: tuple(v) for k, v in aggs.items()}
        nw = self.ctx.nworkers
        if pre_combine is None:
            # planning reads row counts (a blocking device->host sync), so it
            # only runs when the caller left the strategy to the planner.
            card = cardinality_hint if cardinality_hint is not None else 0.0
            plan = patterns.plan_groupby(card, nw, capacity or self.capacity,
                                         n_rows=self.num_rows())
            pre_combine = plan.strategy == "combine_shuffle_reduce"
            if num_chunks is None:
                num_chunks = plan.num_chunks
        num_chunks = num_chunks or 1
        quota = quota or default_quota(self.capacity, nw)
        capacity = capacity or self.capacity
        key = ("groupby", by, tuple(sorted(aggs.items())), pre_combine, quota,
               capacity, num_chunks)
        return self._run(key, lambda comm, t: operators.dist_groupby(
            comm, t, by, aggs, quota, capacity, pre_combine, num_chunks=num_chunks))

    def unique(self, subset: Sequence[str], quota: int | None = None, capacity: int | None = None,
               num_chunks: int = 1):
        """Distinct rows by ``subset`` key columns (combine-shuffle-reduce)."""
        subset = tuple(subset)
        nw = self.ctx.nworkers
        quota = quota or default_quota(self.capacity, nw)
        capacity = capacity or self.capacity
        return self._run(("unique", subset, quota, capacity, num_chunks),
                         lambda comm, t: operators.dist_unique(
                             comm, t, subset, quota, capacity, num_chunks=num_chunks))

    def union(self, other: "DDF", on: Sequence[str], quota: int | None = None,
              capacity: int | None = None, num_chunks: int = 1):
        """Set union by key (concat + distributed unique, paper Table 2)."""
        on = tuple(on)
        nw = self.ctx.nworkers
        cap = self.capacity + other.capacity
        quota = quota or default_quota(cap, nw)
        capacity = capacity or cap
        return self._run(("union", on, quota, capacity, num_chunks),
                         lambda comm, l, r: operators.dist_union(
                             comm, l, r, on, quota, capacity, num_chunks=num_chunks),
                         other)

    def difference(self, other: "DDF", on: Sequence[str], quota: int | None = None,
                   capacity: int | None = None, num_chunks: int = 1):
        """Set difference by key (co-partition + local anti-join)."""
        on = tuple(on)
        nw = self.ctx.nworkers
        quota = quota or default_quota(self.capacity, nw)
        capacity = capacity or self.capacity
        return self._run(("difference", on, quota, capacity, num_chunks),
                         lambda comm, l, r: operators.dist_difference(
                             comm, l, r, on, quota, capacity, num_chunks=num_chunks),
                         other)

    def sort_values(self, by: str, descending: bool = False, quota: int | None = None,
                    capacity: int | None = None, num_chunks: int = 1):
        """Global sample sort by ``by``; partition i gets the i-th key range.
        ``num_chunks`` > 1 pipelines the range shuffle against the merge."""
        nw = self.ctx.nworkers
        quota = quota or default_quota(self.capacity, nw, safety=3.0)
        capacity = capacity or 2 * self.capacity
        return self._run(("sort", by, descending, quota, capacity, num_chunks),
                         lambda comm, t: operators.dist_sort(
                             comm, t, by, quota, capacity, descending=descending,
                             num_chunks=num_chunks))

    def agg(self, column: str, op: str):
        out = self._run(("agg", column, op),
                        lambda comm, t: (operators.dist_column_agg(comm, t, column, op),))
        return np.asarray(out)[0]  # replicated; take worker 0's copy

    def length(self) -> int:
        out = self._run(("length",), lambda comm, t: (operators.dist_length(comm, t),))
        return int(np.asarray(out)[0])

    def rolling_sum(self, column: str, window: int):
        return self._run(("rolling", column, window),
                         lambda comm, t: operators.dist_window_sum(comm, t, column, window))

    def rolling(self, column: str, window: int, op: str = "sum"):
        """Rolling window aggregate: sum | mean | min | max (halo exchange)."""
        return self._run(("rollagg", column, window, op),
                         lambda comm, t: operators.dist_window_agg(comm, t, column, window, op))

    def transpose(self) -> "DDF":
        """Distributed transpose (gather-based; for matrix-shaped tables)."""
        return self._run(("transpose", self.capacity),
                         lambda comm, t: operators.dist_transpose(comm, t))

    def rebalance(self, quota: int | None = None, num_chunks: int = 1):
        """Evenly redistribute rows across workers, preserving global order."""
        quota = quota or self.capacity
        return self._run(("rebalance", quota, num_chunks),
                         lambda comm, t: operators.rebalance(
                             comm, t, quota, num_chunks=num_chunks))

    def head(self, k: int) -> "DDF":
        return self._run(("head", k), lambda comm, t: operators.dist_head(comm, t, k))
