"""Deterministic testing utilities for the streaming engine.

``repro.testing.faults`` is the seeded fault-injection harness: named
fault sites threaded through the streaming runner, a :class:`FaultPlan`
that fails specific invocations deterministically from a seed, and the
``fault_scope`` context manager chaos tests use to install one. See
``docs/FAULT_TOLERANCE.md`` for the fault-site registry and the
determinism contract.
"""

from .faults import (  # noqa: F401
    FAULT_SITES,
    FaultPlan,
    InjectedFault,
    active_plan,
    check,
    fault_scope,
)

__all__ = ["FAULT_SITES", "FaultPlan", "InjectedFault", "active_plan",
           "check", "fault_scope"]
