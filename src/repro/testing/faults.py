"""Deterministic fault-injection harness (ISSUE 6 tentpole).

Chaos testing a streaming engine is only useful when every failure is
reproducible: a flaky test that injects faults at *random* points cannot be
re-run, bisected, or minimized. This module makes fault injection a pure
function of a seed and the runtime's call sequence:

- **Fault sites** are named instrumentation points threaded through the
  streaming runner (``FAULT_SITES``): chunk decode, the prefetch thread,
  the compiled device op, spill writes, and checkpoint publication. Each
  site calls :func:`check` exactly once per unit of work it performs.
- A :class:`FaultPlan` decides — deterministically, from its seed and the
  per-site invocation ordinal — whether a given ``check`` raises
  :class:`InjectedFault`. Two modes compose:

  * ``rates={site: p}`` — *transient* faults: invocation ``n`` of a site
    fails iff the n-th draw of that site's seeded RNG is below ``p``.
    A retry re-invokes the site with the next ordinal, so transient
    faults exercise the retry path and then pass.
  * ``kill_after={site: n}`` — *persistent* faults: every invocation with
    ordinal >= ``n`` fails, guaranteeing retries exhaust and the query
    dies — the checkpoint/resume path's trigger.

- :func:`fault_scope` activates a plan process-wide (the prefetch thread
  must see it too, so this is intentionally not thread-local).

The contract: given the same seed, the same pipeline, and the same
configuration, the exact same invocations fail. Every chaos test in
``tests/test_fault_tolerance.py`` is reproducible from its seed.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Mapping

import numpy as np

__all__ = [
    "FAULT_SITES",
    "FaultPlan",
    "InjectedFault",
    "active_plan",
    "check",
    "fault_scope",
]

#: Registry of instrumented fault sites in the streaming runner.
FAULT_SITES = (
    "chunk_decode",        # host-side dataset chunk decode (read_rows)
    "prefetch",            # inside the double-buffering prefetch thread
    "device_op",           # the compiled per-morsel shard_map program
    "spill_write",         # appending a batch to a host-side spill dataset
    "checkpoint_publish",  # atomic tmp-dir-rename checkpoint publication
)


class InjectedFault(RuntimeError):
    """A deterministic injected failure (always classified retryable)."""

    def __init__(self, site: str, ordinal: int):
        super().__init__(
            f"injected fault at site '{site}' (invocation #{ordinal})")
        self.site = site
        self.ordinal = ordinal


class FaultPlan:
    """Seeded, deterministic schedule of failures over the fault sites.

    Args:
      seed: master seed; each site gets an independent RNG derived from
        ``(seed, site index)``, so adding a rate for one site never
        perturbs another site's draw sequence.
      rates: ``{site: probability}`` of a transient fault per invocation.
      kill_after: ``{site: ordinal}`` — every invocation with ordinal >=
        the threshold fails (persistent; exhausts any retry budget).
      max_failures: cap on the total number of *transient* fires (rates
        only), so a high-rate plan still lets the stream finish.

    Thread-safe: the runner's prefetch thread and consumer thread hit
    sites concurrently; ordinals are assigned under a lock per site, and
    the per-site RNG stream makes the outcome a function of the ordinal
    alone.
    """

    def __init__(self, seed: int = 0,
                 rates: Mapping[str, float] | None = None,
                 kill_after: Mapping[str, int] | None = None,
                 max_failures: int | None = None):
        for site in list(rates or ()) + list(kill_after or ()):
            if site not in FAULT_SITES:
                raise ValueError(f"unknown fault site {site!r}; registered "
                                 f"sites: {list(FAULT_SITES)}")
        self.seed = int(seed)
        self.rates = dict(rates or {})
        self.kill_after = dict(kill_after or {})
        self.max_failures = max_failures
        self._lock = threading.Lock()
        self._counts: dict[str, int] = {}
        self._draws: dict[str, np.random.Generator] = {}
        self.fired: list[tuple[str, int]] = []

    def _rng(self, site: str) -> np.random.Generator:
        if site not in self._draws:
            self._draws[site] = np.random.default_rng(
                np.random.SeedSequence([self.seed, FAULT_SITES.index(site)]))
        return self._draws[site]

    def invocations(self, site: str) -> int:
        """How many times ``site`` has been checked under this plan."""
        with self._lock:
            return self._counts.get(site, 0)

    def reset(self) -> None:
        """Forget all invocation counts and draws (fresh deterministic run)."""
        with self._lock:
            self._counts.clear()
            self._draws.clear()
            self.fired.clear()

    def check(self, site: str) -> None:
        """Record one invocation of ``site``; raise if it is scheduled to
        fail. Deterministic in (seed, site, ordinal)."""
        with self._lock:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
            fire = False
            if site in self.kill_after and n >= self.kill_after[site]:
                fire = True
            elif site in self.rates:
                would = float(self._rng(site).random()) < self.rates[site]
                capped = (self.max_failures is not None
                          and len(self.fired) >= self.max_failures)
                fire = would and not capped
            if fire:
                self.fired.append((site, n))
        if fire:
            raise InjectedFault(site, n)


_ACTIVE: FaultPlan | None = None
_ACTIVE_LOCK = threading.Lock()


def active_plan() -> FaultPlan | None:
    """The currently-installed :class:`FaultPlan` (None outside chaos tests)."""
    return _ACTIVE


@contextlib.contextmanager
def fault_scope(plan: FaultPlan) -> Iterator[FaultPlan]:
    """Activate ``plan`` for the dynamic extent of the ``with`` block.

    Process-wide on purpose: the runner's prefetch thread must observe the
    plan installed by the test's main thread. Nested scopes restore the
    previous plan on exit.
    """
    global _ACTIVE
    with _ACTIVE_LOCK:
        prev, _ACTIVE = _ACTIVE, plan
    try:
        yield plan
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = prev


def check(site: str) -> None:
    """Fault-site hook: no-op unless a :class:`FaultPlan` is active.

    Production code calls this at each registered site; the cost without an
    active plan is one global read, so the hooks stay compiled into the
    host-side hot paths permanently.
    """
    if site not in FAULT_SITES:
        raise ValueError(f"unknown fault site {site!r}; registered sites: "
                         f"{list(FAULT_SITES)}")
    plan = _ACTIVE
    if plan is not None:
        plan.check(site)
