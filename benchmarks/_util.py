"""Shared benchmark utilities: timing + CSV contract (name,us_per_call,derived)."""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, repeat: int = 3) -> float:
    """Median wall seconds per call (blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def ensure_devices(n: int = 8):
    """Must be called before jax import in __main__ blocks; here just checks."""
    import os
    flags = os.environ.get("XLA_FLAGS", "")
    assert f"device_count={n}" in flags or len(jax.devices()) >= n, (
        f"run via benchmarks.run (needs {n} host devices), got {len(jax.devices())}")
