"""Expression-compiled operators vs the legacy callable path (ISSUE 4).

Runs a select -> derive -> groupby pipeline over 8 host devices four ways —
{callable, expression} x {eager, lazy-optimized} — asserting all four are
bit-identical before timing anything. Expressions compile to the same XLA
as the callables (the win is analyzability: exact pushdown sets, structural
cache keys, host-compilable scan predicates, no probe), so the acceptance
bar is parity: the expression path must be within 20% of the callable path
in steady state. Also times cold plan-build (callable probe + fingerprint
vs expression validation) and writes ``BENCH_EXPR.json`` next to this file.
"""

import json
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import DDF, DDFContext
from repro.expr import col

N = 240_000
KEYS = 64


def make_table(ctx):
    rng = np.random.default_rng(0)
    cap = 2 * (-(-N // ctx.nworkers))
    data = {"k": rng.integers(0, KEYS, N).astype(np.int32),
            "v": rng.integers(0, 1000, N).astype(np.int32),
            "junk_a": rng.integers(0, 5, N).astype(np.int32),
            "junk_b": rng.integers(0, 5, N).astype(np.int32)}
    return DDF.from_numpy(data, ctx, capacity=cap)


def _pred_callable(c):
    return (c["v"] % 3 != 0) & (c["k"] < 48)


_PRED_EXPR = (col("v") % 3).ne(0) & (col("k") < 48)
_DERIVE_EXPR = col("v") * 2 + col("k")


def eager_callable(d):
    s = d.select(_pred_callable, name="bench")
    m = s.map_columns(lambda c: {**c, "d": c["v"] * 2 + c["k"]}, name="derive")
    g, _ = m.groupby(("k",), {"d": ("sum", "count")})
    return g


def eager_expr(d):
    s = d.select(_PRED_EXPR, name="bench")
    m = s.with_column("d", _DERIVE_EXPR)
    g, _ = m.groupby(("k",), [col("d").sum(), col("d").count()])
    return g


def lazy_callable(d):
    return (d.lazy().select(_pred_callable, name="bench")
            .map_columns(lambda c: {**c, "d": c["v"] * 2 + c["k"]},
                         name="derive")
            .groupby(("k",), {"d": ("sum", "count")})).collect()


def lazy_expr(d):
    return (d.lazy().select(_PRED_EXPR, name="bench")
            .with_column("d", _DERIVE_EXPR)
            .groupby(("k",), [col("d").sum(), col("d").count()])).collect()


def main():
    import warnings
    warnings.simplefilter("ignore", DeprecationWarning)
    nd = len(jax.devices())
    mesh = jax.make_mesh((nd,), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    d = make_table(ctx)

    # correctness first: all four variants bit-identical
    ref = eager_callable(d).to_numpy()
    variants = {"eager_expr": eager_expr(d).to_numpy(),
                "lazy_callable": lazy_callable(d).to_numpy(),
                "lazy_expr": lazy_expr(d).to_numpy()}
    for vname, got in variants.items():
        assert sorted(ref) == sorted(got), vname
        for k in ref:
            assert np.array_equal(ref[k], got[k]), (vname, k)

    t_eager_call = time_fn(lambda: eager_callable(d).counts, repeat=5)
    t_eager_expr = time_fn(lambda: eager_expr(d).counts, repeat=5)
    t_lazy_call = time_fn(lambda: lazy_callable(d).counts, repeat=5)
    t_lazy_expr = time_fn(lambda: lazy_expr(d).counts, repeat=5)

    # cold build cost: plan construction + validation, no execution
    def build_lazy_expr():
        return (d.lazy().select(_PRED_EXPR)
                .with_column("d", _DERIVE_EXPR)
                .groupby(("k",), [col("d").sum()]).plan)

    def build_lazy_callable():
        return (d.lazy().select(_pred_callable)
                .map_columns(lambda c: {**c, "d": c["v"] * 2 + c["k"]})
                .groupby(("k",), {"d": ("sum",)}).plan)

    t_build_expr = time_fn(build_lazy_expr, repeat=20)
    t_build_call = time_fn(build_lazy_callable, repeat=20)

    emit("expr/eager_callable", t_eager_call, f"P={nd}")
    emit("expr/eager_expr", t_eager_expr,
         f"P={nd},ratio={t_eager_call / t_eager_expr:.3f}")
    emit("expr/lazy_callable", t_lazy_call, f"P={nd}")
    emit("expr/lazy_expr", t_lazy_expr,
         f"P={nd},ratio={t_lazy_call / t_lazy_expr:.3f}")
    emit("expr/build_callable", t_build_call, "probe+fingerprint")
    emit("expr/build_expr", t_build_expr,
         f"ratio={t_build_call / t_build_expr:.3f}")

    record = {
        "P": nd,
        "rows": N,
        "pipeline": "select -> derive column -> groupby",
        "t_eager_callable_s": t_eager_call,
        "t_eager_expr_s": t_eager_expr,
        "t_lazy_callable_s": t_lazy_call,
        "t_lazy_expr_s": t_lazy_expr,
        "t_build_plan_callable_s": t_build_call,
        "t_build_plan_expr_s": t_build_expr,
        "expr_over_callable_eager": t_eager_call / t_eager_expr,
        "expr_over_callable_lazy": t_lazy_call / t_lazy_expr,
        "bit_identical": True,
    }
    out_path = os.path.join(os.path.dirname(__file__), "BENCH_EXPR.json")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    assert t_lazy_expr <= 1.2 * t_lazy_call, (
        f"expression path {t_lazy_expr:.3f}s regressed >20% vs callable "
        f"{t_lazy_call:.3f}s")
    print(f"expr vs callable: eager {t_eager_call / t_eager_expr:.2f}x, "
          f"lazy {t_lazy_call / t_lazy_expr:.2f}x, "
          f"plan-build {t_build_call / t_build_expr:.2f}x", flush=True)


if __name__ == "__main__":
    main()
