"""Observability overhead + cost-model accounting benchmark (ISSUE 8).

Runs the standard 4-op pipeline (select -> project -> shuffle join ->
groupby) on 8 host devices two ways — tracing disabled vs tracing
enabled — and asserts:

- results are **bit-identical** (observability never changes answers);
- the traced median is within **3%** of the untraced median (the
  acceptance bound; warm caches, so the comparison isolates span/record
  overhead rather than compile time);
- the per-pattern ``model_report`` for the pipeline is populated, and a
  traced streaming scan -> groupby adds ``partitioned_io`` coverage.

Also measures the disabled-mode null-span cost (the price every engine
call site pays when tracing is off — nanoseconds, by design). Writes
``BENCH_OBS.json`` next to this file.
"""

import json
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks._util import emit
from repro import obs, stream
from repro.core import DDF, DDFContext
from repro.data.dataset import write_dataset
from repro.expr import col
from repro.obs import trace

N_LEFT = 200_000
N_RIGHT = 50_000
KEYS = 20_000
REPEAT = 15
N_DISK = 64_000
N_BATCHES = 8


def four_op(dl, dr):
    return (dl.lazy()
            .select((col("v") % 2).eq(0))
            .project(["k", "v"])
            .join(dr.lazy(), on=("k",), strategy="shuffle",
                  capacity=4 * (-(-N_LEFT // 8)))
            .groupby(("k",), {"v": ("sum", "count")}))


def one_collect(lz):
    t0 = time.perf_counter()
    out = lz.collect()
    jax.block_until_ready(out.counts)
    return time.perf_counter() - t0, out


def main():
    nd = len(jax.devices())
    mesh = jax.make_mesh((nd,), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    rng = np.random.default_rng(0)

    dl = DDF.from_numpy(
        {"k": rng.integers(0, KEYS, N_LEFT).astype(np.int32),
         "v": rng.integers(0, 1000, N_LEFT).astype(np.int32),
         "pad": rng.random(N_LEFT).astype(np.float32)},
        ctx, capacity=2 * (-(-N_LEFT // nd)))
    dr = DDF.from_numpy(
        {"k": rng.integers(0, KEYS, N_RIGHT).astype(np.int32),
         "w": rng.integers(0, 50, N_RIGHT).astype(np.int32)},
        ctx, capacity=2 * (-(-N_RIGHT // nd)))
    lz = four_op(dl, dr)

    # warm both modes once: compiles + first-dispatch costs amortize out of
    # the overhead comparison (first traced dispatch would otherwise charge
    # compile time to "observed" wall)
    one_collect(lz)
    with trace.tracing():
        one_collect(lz)

    # interleave the two modes so clock drift (thermal, page cache) cancels
    # instead of biasing whichever mode runs second
    us, ts = [], []
    for _ in range(REPEAT):
        u, ref = one_collect(lz)
        us.append(u)
        with trace.tracing():
            t, got = one_collect(lz)
        ts.append(t)
    untraced_s, traced_s = float(np.median(us)), float(np.median(ts))
    overhead = traced_s / untraced_s - 1.0

    # bit-identity: tracing must never change the answer
    rn, gn = ref.to_numpy(), got.to_numpy()
    bit_identical = all(np.array_equal(rn[k], gn[k]) for k in rn)
    assert bit_identical, "traced collect diverged from untraced collect"

    # per-pattern model accounting for one profiled run of the pipeline
    with obs.profiled() as prof:
        out = lz.collect()
        jax.block_until_ready(out.counts)
    pipeline_report = prof.report()["model"]

    # streaming scan -> groupby for partitioned_io (decode-side) coverage
    tmp = tempfile.mkdtemp(prefix="repro-bench-obs-")
    man = write_dataset(
        {"k": rng.integers(0, KEYS, N_DISK).astype(np.int32),
         "v": rng.integers(0, 1000, N_DISK).astype(np.int32)},
        tmp, chunk_rows=(N_DISK // N_BATCHES) // 2)
    q = stream.scan_dataset(man, ctx, batch_rows=N_DISK // N_BATCHES) \
        .groupby(("k",), {"v": ("sum", "count")})
    with obs.profiled() as sprof:
        _, sinfo = stream.collect(q)
    stream_report = sprof.report()["model"]
    assert "partitioned_io" in stream_report, (
        f"streaming run recorded no scan samples: {sorted(stream_report)}")
    assert pipeline_report, "4-op pipeline recorded no model samples"

    # disabled-mode null-span cost per call site
    assert not trace.enabled()
    n_null = 200_000
    t0 = time.perf_counter()
    for _ in range(n_null):
        with trace.span("noop"):
            pass
    null_ns = (time.perf_counter() - t0) / n_null * 1e9

    emit("obs/untraced_collect", untraced_s, f"P={nd},rows={N_LEFT}")
    emit("obs/traced_collect", traced_s,
         f"P={nd},overhead={overhead * 100:.2f}%")
    emit("obs/null_span", null_ns * 1e-9, f"{null_ns:.0f}ns_per_disabled_span")
    emit("obs/model_patterns", 0.0,
         "pipeline=" + "|".join(sorted(pipeline_report))
         + ";stream=" + "|".join(sorted(stream_report)))

    record = {
        "P": nd,
        "rows_left": N_LEFT,
        "rows_right": N_RIGHT,
        "repeat": REPEAT,
        "untraced_median_s": untraced_s,
        "traced_median_s": traced_s,
        "overhead_frac": overhead,
        "bit_identical": bit_identical,
        "null_span_ns": null_ns,
        "pipeline_model_report": pipeline_report,
        "stream_model_report": stream_report,
        "stream_peak_working_set_bytes": sinfo.get("peak_working_set_bytes"),
    }
    out_path = os.path.join(os.path.dirname(__file__), "BENCH_OBS.json")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    assert overhead < 0.03, (
        f"tracing overhead {overhead * 100:.2f}% exceeds the 3% bound "
        f"(traced {traced_s * 1e3:.2f}ms vs untraced {untraced_s * 1e3:.2f}ms)")
    print(f"tracing overhead {overhead * 100:+.2f}% "
          f"(traced {traced_s * 1e3:.2f}ms / untraced {untraced_s * 1e3:.2f}ms, "
          f"median of {REPEAT}); disabled span {null_ns:.0f}ns; "
          f"patterns: pipeline={sorted(pipeline_report)} "
          f"stream={sorted(stream_report)}", flush=True)


if __name__ == "__main__":
    main()
