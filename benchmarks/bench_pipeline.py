"""Pipelined chunked shuffle vs monolithic all-to-all (ISSUE 1 tentpole).

Times the hash-partition + shuffle + local-merge hot path over 8 host devices
across table sizes and chunk counts K, then compares the cost model's chosen
K (``cost_model.choose_chunk_count``) against the empirically best K. The
acceptance bar: the model-chosen K's wall time is within 20% of the best
measured K.

Like bench_comm's Hockney fit, the model constants are calibrated from the
measurements (the baked-in HOST profile describes a real NIC, not XLA's
emulated host all-to-all): we least-squares fit the pipelined cost shape
``t(K) = K*alpha' + n*beta' + core/K`` over the measured chunk counts, map
the fit back onto ``CostParams``, and then let ``choose_chunk_count`` pick K.

Emits the standard ``name,us_per_call,derived`` CSV and writes
``BENCH_PIPELINE.json`` next to this file for the README results table.
"""

import json
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks._util import emit, time_fn
from repro.compat import shard_map
from repro.core.comm import collectives
from repro.core.comm.communicator import HOST, FabricProfile
from repro.core.cost_model import CostParams, choose_chunk_count, t_shuffle_pipelined
from repro.core.dataframe import Table
from repro.core.local_ops import local_unique
from repro.core.partition import hash_partition_ids

ROW_BYTES = 8.0  # two int32 columns
CHUNK_COUNTS = (1, 2, 4, 8, 16)


def build_shuffle_fn(mesh, nw, quota, num_chunks):
    """jitted shard_map: hash partition -> (pipelined) shuffle -> local dedup.

    The dedup leg stands in for the pattern's core op so the pipeline has
    compute to overlap, mirroring dist_unique's structure.
    """

    def run(cols, counts):
        t = Table(dict(cols), counts.reshape(()))
        dest = hash_partition_ids(t, ("k",), nw)
        if num_chunks == 1:
            shuf, ov = collectives.shuffle_table(t, dest, "data", quota)
        else:
            shuf, ov = collectives.shuffle_table_pipelined(
                t, dest, "data", quota, num_chunks)
        out = local_unique(shuf, ("k",), capacity=t.capacity)
        return out.nvalid.reshape(1), ov.reshape(1)

    sm = shard_map(run, mesh=mesh,
                   in_specs=({"k": P("data"), "v": P("data")}, P("data")),
                   out_specs=P("data"), check_vma=False)
    return jax.jit(sm)


def calibrate_params(timings: dict, n_bytes_w: float, P: int):
    """Fit the pipelined cost shape to measured (K -> seconds).

    ``t_shuffle_pipelined`` with comm-bound chunks reduces to
    ``t(K) = K*startup + transfer + core/K``; least-squares those three
    constants and express them as a ``CostParams`` (+ core_s) so
    ``choose_chunk_count`` reproduces the fit. Mirrors bench_comm's
    alpha/beta Hockney fit.
    """
    ks = np.array(sorted(timings), float)
    ts = np.array([timings[int(k)] for k in ks])
    A = np.vstack([ks, np.ones_like(ks), 1.0 / ks]).T
    (startup, transfer, core), *_ = np.linalg.lstsq(A, ts, rcond=None)
    startup = max(float(startup), 1e-9)
    transfer = max(float(transfer), 0.0)
    core = max(float(core), 0.0)
    # t_shuffle("isend-irecv"): startup = (P-1)*alpha, transfer = (P-1)/P*n*beta
    alpha = startup / (P - 1)
    beta = transfer / ((P - 1) / P * n_bytes_w)
    fabric = FabricProfile("host-fitted", alpha_s=alpha, beta_s_per_byte=beta)
    return CostParams(fabric=fabric), core


def main():
    nd = len(jax.devices())
    mesh = jax.make_mesh((nd,), ("data",))
    nw = nd
    params = CostParams(fabric=HOST)
    record = {"P": nw, "sizes": {}}

    for n in (40_000, 160_000, 640_000):
        cap = 2 * (n // nw + 1)
        quota = cap  # generous: zero overflow by construction
        rng = np.random.default_rng(0)
        cols = {
            "k": jnp.asarray(rng.integers(0, int(0.9 * n), size=(nw * cap,)).astype(np.int32)),
            "v": jnp.asarray(rng.integers(0, 1000, size=(nw * cap,)).astype(np.int32)),
        }
        counts = jnp.asarray(np.full((nw,), n // nw, np.int32))

        timings = {}
        for k in CHUNK_COUNTS:
            fn = build_shuffle_fn(mesh, nw, quota, k)
            nvalid, ov = fn(cols, counts)
            assert int(np.asarray(ov).sum()) == 0, f"overflow at K={k}"
            t = time_fn(lambda fn=fn: fn(cols, counts)[0])
            timings[k] = t
            emit(f"pipeline/shuffle_n{n}_K{k}", t, f"P={nw}")

        n_bytes_w = (n / nw) * ROW_BYTES
        fit_params, fit_core = calibrate_params(timings, n_bytes_w, nw)
        k_model = choose_chunk_count(nw, n_bytes_w, fit_params, core_s=fit_core,
                                     max_chunks=max(CHUNK_COUNTS),
                                     min_chunk_bytes=1.0)
        k_model = min(timings, key=lambda c: abs(c - k_model))  # snap to measured grid
        # uncalibrated choice from the default HOST profile, for comparison
        k_default = choose_chunk_count(nw, n_bytes_w, params,
                                       core_s=params.gamma_s_per_row * (n / nw),
                                       max_chunks=max(CHUNK_COUNTS))
        k_best = min(timings, key=timings.get)
        ratio = timings[k_model] / timings[k_best]
        emit(f"pipeline/model_choice_n{n}", timings[k_model],
             f"K_model={k_model},K_best={k_best},t_ratio={ratio:.3f},K_default={k_default}")
        pred = {k: t_shuffle_pipelined(nw, n_bytes_w, k, fit_params, core_s=fit_core)
                for k in CHUNK_COUNTS}
        record["sizes"][n] = {
            "timings_s": {str(k): v for k, v in timings.items()},
            "predicted_s": {str(k): v for k, v in pred.items()},
            "K_model": k_model, "K_default": k_default, "K_best": k_best,
            "model_vs_best_ratio": ratio,
            "pipelined_speedup_best": timings[1] / timings[k_best],
        }

    out_path = os.path.join(os.path.dirname(__file__), "BENCH_PIPELINE.json")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    worst = max(v["model_vs_best_ratio"] for v in record["sizes"].values())
    emit("pipeline/model_vs_best_worst_ratio", 0.0, f"ratio={worst:.3f}")


if __name__ == "__main__":
    main()
