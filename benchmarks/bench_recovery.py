"""Fault-tolerance overhead + recovery latency benchmark (ISSUE 6).

Measures the cost of making streaming queries restartable, on the same
4-op pipeline as ``bench_stream`` (select -> project -> join -> groupby)
over an 8-morsel on-disk dataset:

- **fault-free** vs **checkpointed** wall time at the default cadence
  (``checkpoint_every=4``) — the acceptance bound is <= 10% overhead;
- **recovery latency**: kill the query mid-stream with a deterministic
  injected fault (``kill_after`` on ``device_op``), then time the
  ``resume=True`` run back to a verified bit-identical result, reporting
  resume wall vs a full fresh re-run (work saved by the snapshot).

Writes ``BENCH_RECOVERY.json`` next to this file.
"""

import json
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import numpy as np

from benchmarks._util import emit
from repro import stream
from repro.core import DDF, DDFContext
from repro.data.dataset import write_dataset
from repro.testing import FaultPlan, fault_scope

N = 320_000          # on-disk rows
N_RIGHT = 60_000     # in-memory build side
KEYS = 20_000
N_BATCHES = 8        # dataset is 8 morsels
KILL_AT = 5          # device_op invocation ordinal that turns persistent-fatal
CHECKPOINT_EVERY = 4


def make_data():
    rng = np.random.default_rng(0)
    left = {"k": rng.integers(0, KEYS, N).astype(np.int32),
            "v": rng.integers(0, 1000, N).astype(np.int32),
            "junk_a": rng.integers(0, 5, N).astype(np.int32),
            "junk_b": rng.integers(0, 5, N).astype(np.int32)}
    right = {"k": rng.integers(0, KEYS, N_RIGHT).astype(np.int32),
             "w": rng.integers(0, 50, N_RIGHT).astype(np.int32)}
    return left, right


def _pred(c):
    return c["v"] % 2 == 0


def main():
    nd = len(jax.devices())
    mesh = jax.make_mesh((nd,), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    left, right = make_data()
    batch_rows = N // N_BATCHES

    tmp = tempfile.mkdtemp(prefix="repro-bench-recovery-")
    man = write_dataset(left, os.path.join(tmp, "data"),
                        chunk_rows=batch_rows // 2)
    dr = DDF.from_numpy(right, ctx, capacity=2 * (-(-N_RIGHT // nd)))

    def pipeline():
        return (stream.scan_dataset(man, ctx, batch_rows=batch_rows)
                .select(_pred, name="even")
                .project(["k", "v"])
                .join(dr.lazy(), on=("k",), strategy="shuffle")
                .groupby(("k",), {"v": ("sum", "count")}))

    def run(**opts):
        return pipeline().collect_stream(**opts)

    ckpt = os.path.join(tmp, "ckpt")

    def checkpointed():
        shutil.rmtree(ckpt, ignore_errors=True)
        return run(checkpoint_dir=ckpt, checkpoint_every=CHECKPOINT_EVERY)

    # correctness first: checkpointed == fault-free, bit for bit
    ref = run().to_numpy()
    got = checkpointed().to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k

    # Runs last seconds, so wall noise between back-to-back blocks would
    # swamp a small per-snapshot cost; interleave the two configurations
    # and take per-config minima instead of block medians.
    t_plain, t_ckpt = [], []
    for _ in range(4):
        t0 = time.perf_counter()
        jax.block_until_ready(run().counts)
        t_plain.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(checkpointed().counts)
        t_ckpt.append(time.perf_counter() - t0)
    t_plain, t_ckpt = min(t_plain), min(t_ckpt)
    overhead = t_ckpt / t_plain - 1.0

    emit("recovery/fault_free_4op", t_plain, f"P={nd},batches={N_BATCHES}")
    emit("recovery/checkpoint_every_4", t_ckpt,
         f"P={nd},overhead={overhead * 100:.1f}%")

    # recovery latency: kill mid-stream, resume from the snapshot.
    def killed_then_resumed():
        shutil.rmtree(ckpt, ignore_errors=True)
        plan = FaultPlan(seed=0, kill_after={"device_op": KILL_AT})
        try:
            with fault_scope(plan):
                run(checkpoint_dir=ckpt, checkpoint_every=CHECKPOINT_EVERY,
                    max_retries=1, retry_backoff_s=0.0)
            raise AssertionError("injected kill did not fire")
        except Exception:
            pass
        t0 = time.perf_counter()
        out = run(checkpoint_dir=ckpt, resume=True)
        jax.block_until_ready(out.counts)
        return time.perf_counter() - t0, out

    t_resume, out = killed_then_resumed()
    got = out.to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k

    work_saved = 1.0 - t_resume / t_plain
    emit("recovery/resume_after_kill", t_resume,
         f"P={nd},kill_at={KILL_AT},vs_fresh={t_resume / t_plain:.3f}")

    record = {
        "P": nd,
        "rows_on_disk": N,
        "batch_rows": batch_rows,
        "n_batches": N_BATCHES,
        "checkpoint_every": CHECKPOINT_EVERY,
        "pipeline": "select -> project -> join -> groupby",
        "t_fault_free_s": t_plain,
        "t_checkpointed_s": t_ckpt,
        "checkpoint_overhead": overhead,
        "kill_site": "device_op",
        "kill_at_ordinal": KILL_AT,
        "t_resume_s": t_resume,
        "resume_vs_fresh": t_resume / t_plain,
        "resume_bit_identical": True,
    }
    out_path = os.path.join(os.path.dirname(__file__), "BENCH_RECOVERY.json")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    shutil.rmtree(tmp, ignore_errors=True)
    assert overhead <= 0.10, (
        f"checkpoint overhead {overhead * 100:.1f}% exceeds the 10% budget "
        f"at checkpoint_every={CHECKPOINT_EVERY}")
    print(f"checkpoint overhead at every-{CHECKPOINT_EVERY}: "
          f"{overhead * 100:.1f}%; resume after kill@{KILL_AT}: "
          f"{t_resume / t_plain:.2f}x of a fresh run "
          f"({work_saved * 100:.0f}% of work saved), bit-identical",
          flush=True)


if __name__ == "__main__":
    main()
