"""Paper Table 3: communication-collective costs vs the Hockney model.

Measures shuffle (all-to-all), allgather, broadcast, allreduce on tables of
increasing size over 8 host devices, fits T = alpha + n*beta per collective,
and reports the measured-vs-model agreement the paper's cost model predicts
(T_startup + T_transfer structure)."""

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import DDF, DDFContext
from repro.core.cost_model import CostParams, t_allreduce, t_shuffle, t_allgather
from repro.data.synthetic import uniform_table


def main():
    nd = len(jax.devices())
    mesh = jax.make_mesh((nd,), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    P = nd

    sizes = [10_000, 40_000, 160_000]
    results = {}
    for n in sizes:
        data = uniform_table(n, cardinality=0.9)
        d = DDF.from_numpy(data, ctx, capacity=2 * (n // P + 1))
        row_bytes = 8.0  # two int32 columns

        # shuffle: hash-partition + all_to_all (isolate comm via unique's
        # shuffle with pre_combine disabled and near-trivial local op)
        t_sh = time_fn(lambda d=d: d.unique(("c0",), capacity=d.capacity)[0].counts)
        # allgather (broadcast-join path gathers the small side)
        t_ag = time_fn(lambda d=d: d.join(d, on=("c0",), strategy="broadcast",
                                          capacity=4 * d.capacity)[0].counts)
        # allreduce (column agg)
        t_ar = time_fn(lambda d=d: d.agg("c1", "sum"))
        results[n] = (t_sh, t_ag, t_ar)
        emit(f"comm/shuffle_n{n}", t_sh, f"P={P}")
        emit(f"comm/allgather_n{n}", t_ag, f"P={P}")
        emit(f"comm/allreduce_n{n}", t_ar, f"P={P}")

    # Hockney fit on the shuffle: T(n) = a + b*n  (least squares over sizes)
    ns = np.array(sizes, float)
    ts = np.array([results[n][0] for n in sizes])
    A = np.vstack([np.ones_like(ns), ns]).T
    (alpha, beta), *_ = np.linalg.lstsq(A, ts, rcond=None)
    emit("comm/hockney_alpha", max(alpha, 0.0), "fitted startup s")
    emit("comm/hockney_beta_per_row", max(beta, 0.0), "fitted s/row")
    # model agreement: predicted ratio T(160k)/T(10k) vs measured
    p = CostParams()
    pred = sum(t_shuffle(P, 160_000 / P * 8, p)) / sum(t_shuffle(P, 10_000 / P * 8, p))
    meas = ts[-1] / ts[0]
    emit("comm/shuffle_scaling_ratio", 0.0, f"model={pred:.2f},measured={meas:.2f}")


if __name__ == "__main__":
    main()
