"""Dict-encoded string column benchmark (ISSUE 10).

Two claims:

- **Codes are (nearly) free**: a string-keyed join -> groupby pipeline over
  dict-encoded columns runs the *same* device program as a pre-coded
  ``int32`` baseline — the only extra work is host-side vocab metadata,
  one ``Recode`` gather at the join boundary, and decode-on-collect. The
  dict/int wall-time ratio is reported and the two results are asserted
  equal (codes decoded through the merged vocabulary).
- **Recode overhead is one gather**: the isolated cost of vocab
  unification (host sorted-merge + ``recode_map`` + device ``take`` over
  the large side's code column) is measured on its own.

Writes ``BENCH_TYPES.json`` next to this file.
"""

import json
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import DDF, DDFContext
from repro.core.vocab import DictVocab

N_LEFT = 32_768
N_WORDS = 1_000
RIGHT_POOL = 800          # right holds words[:800]; left draws from words[200:]
REPEAT = 3
CAP = 2 * N_LEFT


def _canon(host):
    order = np.lexsort(tuple(np.asarray(host[k]) for k in sorted(host)))
    return {k: np.asarray(v)[order] for k, v in host.items()}


def _make_data():
    words = np.asarray([f"key{i:04d}" for i in range(N_WORDS)])
    rng = np.random.default_rng(0)
    # Left draws from the *upper* 800 words so its vocab is NOT a prefix of
    # the merged vocab -> the big side is the one that needs the recode.
    left_idx = rng.integers(200, N_WORDS, N_LEFT)
    left = {"k": words[left_idx],
            "v": rng.integers(0, 100, N_LEFT).astype(np.int32)}
    right = {"k": words[:RIGHT_POOL],
             "w": np.arange(RIGHT_POOL, dtype=np.int32)}
    return words, left, right


def _pipeline(left, right):
    return (left.lazy()
            .join(right.lazy(), on=("k",))
            .groupby(("k",), {"v": ("sum", "count")}))


def _run_timed(left, right):
    ts, out = [], None
    for _ in range(REPEAT):
        t0 = time.perf_counter()
        out = _pipeline(left, right).collect().to_numpy()
        ts.append(time.perf_counter() - t0)
    return _canon(out), float(np.median(ts))


def bench_join_groupby(ctx):
    words, left, right = _make_data()
    d_left = DDF.from_numpy(left, ctx, capacity=CAP)
    d_right = DDF.from_numpy(right, ctx, capacity=CAP)

    # Pre-coded baseline: both sides encoded up front into the merged vocab,
    # so the pipeline is pure int32 with no Recode and no decode-on-collect.
    merged = DictVocab.from_values(left["k"]).merge(
        DictVocab.from_values(right["k"]))
    i_left = DDF.from_numpy(
        {"k": merged.encode(left["k"]), "v": left["v"]}, ctx, capacity=CAP)
    i_right = DDF.from_numpy(
        {"k": merged.encode(right["k"]), "w": right["w"]}, ctx, capacity=CAP)

    _pipeline(d_left, d_right).collect()   # warm compile caches
    _pipeline(i_left, i_right).collect()
    out_dict, t_dict = _run_timed(d_left, d_right)
    out_int, t_int = _run_timed(i_left, i_right)

    # Same answer: the int baseline's key codes decode to the dict run's keys.
    out_int = _canon({**out_int, "k": merged.decode(out_int["k"])})
    assert set(out_dict) == set(out_int)
    for c in out_dict:
        assert np.array_equal(out_dict[c], out_int[c]), c

    ratio = t_dict / max(t_int, 1e-9)
    emit("types_join_groupby_dict", t_dict,
         f"{len(out_dict['k'])} groups; recode on {N_LEFT}-row side")
    emit("types_join_groupby_int", t_int, "pre-coded int32 baseline")
    emit("types_dict_over_int", t_dict - t_int, f"x{ratio:.3f}")
    return {
        "rows_left": N_LEFT,
        "vocab_words": N_WORDS,
        "seconds_dict": t_dict,
        "seconds_int_baseline": t_int,
        "dict_over_int_ratio": ratio,
        "bit_identical": True,
    }


def bench_recode_overhead(ctx):
    words, left, right = _make_data()
    lv = DictVocab.from_values(left["k"])
    rv = DictVocab.from_values(right["k"])

    def host_merge():
        merged = lv.merge(rv)
        return lv.recode_map(merged)

    rmap = host_merge()
    t_host = time_fn(lambda: jnp.zeros(()) + host_merge()[0],
                     warmup=1, repeat=REPEAT)
    codes = jnp.asarray(lv.encode(left["k"]))
    rmap_dev = jnp.asarray(rmap)
    t_gather = time_fn(lambda: jnp.take(rmap_dev, codes),
                       warmup=1, repeat=REPEAT)
    emit("types_recode_host_merge", t_host,
         f"merge+map over {len(lv.words)}+{len(rv.words)} words")
    emit("types_recode_gather", t_gather, f"{N_LEFT}-row int32 take")
    return {
        "seconds_host_merge": t_host,
        "seconds_device_gather": t_gather,
        "map_width": int(len(rmap)),
    }


def main():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    results = {
        "join_groupby": bench_join_groupby(ctx),
        "recode": bench_recode_overhead(ctx),
    }
    out_path = os.path.join(os.path.dirname(__file__), "BENCH_TYPES.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    emit("types_total", 0.0, f"wrote {os.path.basename(out_path)}")


if __name__ == "__main__":
    main()
