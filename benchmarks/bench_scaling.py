"""Paper Figs 10-11 + Table 5: Summit-style strong/weak scaling, measured at
P<=8 host devices and projected to Summit parallelisms (168..10752 cores)
with the calibrated cost model — the same extrapolation the paper's §6.1.1
performs analytically."""

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import DDF, DDFContext
from repro.core.cost_model import CostParams, pattern_cost
from repro.core.comm.communicator import FabricProfile
from repro.data.synthetic import uniform_table


def main():
    nd = len(jax.devices())
    # --- weak scaling (Table 5 / Fig 11): fixed rows per worker ------------
    per_worker = 25_000  # scaled-down from the paper's 25M/worker
    throughputs = {}
    for p in (1, 2, 4, 8):
        if p > nd:
            continue
        devs = jax.devices()[:p]
        mesh = jax.sharding.Mesh(np.array(devs), ("data",))
        ctx = DDFContext(mesh=mesh, axes=("data",))
        n = per_worker * p
        cap = 2 * per_worker + 2
        L = DDF.from_numpy(uniform_table(n, 0.9, seed=1), ctx, capacity=cap)
        R = DDF.from_numpy(uniform_table(n, 0.9, seed=2), ctx, capacity=cap)
        t = time_fn(lambda: L.join(R, on=("c0",), strategy="shuffle",
                                   capacity=4 * cap)[0].counts)
        thr = 2 * n / t
        throughputs[p] = thr
        emit(f"table5/weak_P{p}", t, f"tuples_per_s={thr:.0f}")

    # --- strong scaling (Fig 10): fixed total ------------------------------
    total = 160_000
    for p in (1, 2, 4, 8):
        if p > nd:
            continue
        devs = jax.devices()[:p]
        mesh = jax.sharding.Mesh(np.array(devs), ("data",))
        ctx = DDFContext(mesh=mesh, axes=("data",))
        cap = 2 * (total // p + 1)
        L = DDF.from_numpy(uniform_table(total, 0.9, seed=1), ctx, capacity=cap)
        R = DDF.from_numpy(uniform_table(total, 0.9, seed=2), ctx, capacity=cap)
        t = time_fn(lambda: L.join(R, on=("c0",), strategy="shuffle",
                                   capacity=4 * cap)[0].counts)
        emit(f"fig10/strong_P{p}", t, f"rows={total}")

    # --- cost-model projection to Summit parallelisms (Fig 10b trend) -------
    # calibrate gamma from measured P=1 time; IB fabric like Summit
    if 1 in throughputs:
        ib = FabricProfile("ib", alpha_s=2e-6, beta_s_per_byte=1.0 / 5e9)
        params = CostParams(fabric=ib, gamma_s_per_row=2e-8)
        for p in (168, 672, 2688, 10752):
            c = pattern_cost("shuffle_compute", P=p, n_rows=50_000_000 / p * 2,
                             row_bytes=16, cardinality=0.9, core_op="sort_join",
                             params=params)
            emit(f"fig10/projected_P{p}", c["total"],
                 f"comm_frac={c['comm'] / c['total']:.2f}")


if __name__ == "__main__":
    main()
