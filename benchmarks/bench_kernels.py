"""ISSUE 5: Pallas dataframe kernels vs the jnp hot paths + dispatch audit.

Three sections, written to ``benchmarks/BENCH_KERNELS.json`` (and the
shared ``name,us_per_call,derived`` CSV):

1. **per-kernel timings** — ``hash_partition`` and ``segment_reduce`` across
   sizes on the jnp path and the Pallas path (native on TPU; on this CPU
   container the Pallas path runs ``interpret=True``, which is a
   correctness mode, not a performance mode — the recorded speedup then
   documents *why* ``auto`` dispatch keeps CPU on jnp);
2. **parity** — both kernels asserted bit-identical between the two paths
   on every benchmarked size (integer data, so exactness is unconditional);
3. **dispatch audit** — for a grid of (kernel, rows, dtype, backend
   override), the decision ``registry.resolve`` makes is checked against
   the calibrated ``cost_model.kernel_params`` prediction.
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import cost_model
from repro.kernels import ops, ref, registry

SIZES = [8_192, 65_536, 262_144]
P = 64
NSEG_FRACTION = 16  # segments = rows / 16


def bench_hash_partition(results: dict) -> None:
    pallas_mode = "pallas" if ops.on_tpu() else "interpret"
    for n in SIZES:
        rng = np.random.default_rng(n)
        keys = jnp.asarray(rng.integers(0, 1 << 31, size=(n, 2)).astype(np.uint32))

        f_jnp = jax.jit(lambda k: ref.hash_partition_ref(k, P))
        f_pal = jax.jit(lambda k: ops.hash_partition(k, P, force=pallas_mode))
        t_jnp = time_fn(lambda k: f_jnp(k)[0], keys)
        t_pal = time_fn(lambda k: f_pal(k)[0], keys)

        dj, hj = f_jnp(keys)
        dp, hp = f_pal(keys)
        exact = bool(jnp.array_equal(dj, dp)) and bool(jnp.array_equal(hj, hp))
        assert exact, f"hash_partition parity failed at n={n}"

        speedup = t_jnp / t_pal
        emit(f"kernels/hash_partition_n{n}_jnp", t_jnp, f"per_row={t_jnp / n:.3e}")
        emit(f"kernels/hash_partition_n{n}_{pallas_mode}", t_pal,
             f"speedup_vs_jnp={speedup:.3f}x exact={exact}")
        results["hash_partition"].append(
            {"rows": n, "jnp_s": t_jnp, "pallas_s": t_pal,
             "pallas_mode": pallas_mode, "speedup": speedup, "exact": exact})


def bench_segment_reduce(results: dict) -> None:
    pallas_mode = "pallas" if ops.on_tpu() else "interpret"
    for n in SIZES:
        rng = np.random.default_rng(n + 1)
        nseg = max(n // NSEG_FRACTION, 1)
        vals = jnp.asarray(rng.integers(-1000, 1000, size=(n, 1)).astype(np.int32))
        seg = jnp.asarray(np.sort(rng.integers(0, nseg, n)).astype(np.int32))

        f_jnp = jax.jit(lambda v, s: ref.segment_reduce_ref(v, s, nseg))
        f_pal = jax.jit(lambda v, s: ops.segment_reduce(v, s, nseg,
                                                        force=pallas_mode))
        t_jnp = time_fn(f_jnp, vals, seg)
        t_pal = time_fn(f_pal, vals, seg)

        exact = bool(jnp.array_equal(f_jnp(vals, seg), f_pal(vals, seg)))
        assert exact, f"segment_reduce parity failed at n={n}"

        speedup = t_jnp / t_pal
        emit(f"kernels/segment_reduce_n{n}_jnp", t_jnp, f"per_row={t_jnp / n:.3e}")
        emit(f"kernels/segment_reduce_n{n}_{pallas_mode}", t_pal,
             f"speedup_vs_jnp={speedup:.3f}x exact={exact}")
        results["segment_reduce"].append(
            {"rows": n, "jnp_s": t_jnp, "pallas_s": t_pal,
             "pallas_mode": pallas_mode, "speedup": speedup, "exact": exact})


def audit_dispatch(results: dict) -> None:
    """Check registry decisions against the calibrated model for the full
    (kernel, rows, dtype, override) grid."""
    params = registry.current_params()
    mismatches = 0
    for kernel in registry.KERNEL_OPS:
        thr = params.min_rows[kernel]
        for rows in (1, thr - 1, thr, 16 * thr):
            for dtype in (None, "int32", "float32", "float64"):
                if kernel == "segment_reduce" and dtype is None:
                    continue
                for override in ("auto", "pallas", "jnp"):
                    with registry.use_backend(override):
                        got = registry.resolve(kernel, rows, dtype)
                    supported = params.dtype_supported(kernel, dtype) \
                        if dtype is not None else True
                    if override == "jnp" or not supported:
                        want = "jnp"
                    elif override == "pallas":
                        want = "pallas" if params.native else "interpret"
                    else:
                        want = "pallas" if (params.native and rows >= thr) \
                            else "jnp"
                    ok = got == want
                    mismatches += 0 if ok else 1
                    results["dispatch"].append(
                        {"kernel": kernel, "rows": rows, "dtype": dtype,
                         "override": override, "decision": got,
                         "expected": want, "ok": ok})
    assert mismatches == 0, f"{mismatches} dispatch decisions off-model"
    emit("kernels/dispatch_audit", 0.0,
         f"decisions={len(results['dispatch'])} mismatches={mismatches}")


def main() -> None:
    results: dict = {"jax_backend": jax.default_backend(),
                     "kernel_params": {
                         k: {"min_rows": registry.current_params().min_rows[k],
                             "block": registry.current_params().block[k]}
                         for k in registry.KERNEL_OPS},
                     "hash_partition": [], "segment_reduce": [],
                     "dispatch": []}
    bench_hash_partition(results)
    bench_segment_reduce(results)
    audit_dispatch(results)
    out = os.path.join(os.path.dirname(__file__), "BENCH_KERNELS.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=2)
    emit("kernels/json", 0.0, f"wrote {out}")


if __name__ == "__main__":
    main()
