"""Out-of-core streaming engine benchmark (ISSUE 3 tentpole).

Runs the 4-op pipeline ``select -> project -> join -> groupby`` over a
chunked on-disk dataset ~8x one batch's per-device footprint, three ways:

- **monolithic**: the whole dataset materialized on device first, then the
  lazy-optimized pipeline (the "when-it-fits" baseline — the thing that
  stops existing once the data outgrows device memory);
- **stream (no overlap)**: morsel-driven batches with serial host decode
  (``prefetch=False``) — out-of-core, but decode and device execution
  alternate;
- **stream (overlap)**: double-buffered decode — host-side chunk decode of
  batch *k+1* overlaps device execution of batch *k*.

Asserts streamed results match the monolithic run bit-for-bit and that
decode/compute overlap beats non-overlapped streaming; writes
``BENCH_STREAM.json`` next to this file.
"""

import json
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks._util import emit, time_fn
from repro import stream
from repro.core import DDF, DDFContext
from repro.data.dataset import write_dataset

N = 320_000          # on-disk rows
N_RIGHT = 60_000     # in-memory build side
KEYS = 20_000
N_BATCHES = 8        # dataset is 8x one batch


def make_data():
    rng = np.random.default_rng(0)
    left = {"k": rng.integers(0, KEYS, N).astype(np.int32),
            "v": rng.integers(0, 1000, N).astype(np.int32),
            "junk_a": rng.integers(0, 5, N).astype(np.int32),
            "junk_b": rng.integers(0, 5, N).astype(np.int32)}
    right = {"k": rng.integers(0, KEYS, N_RIGHT).astype(np.int32),
             "w": rng.integers(0, 50, N_RIGHT).astype(np.int32)}
    return left, right


def _pred(c):
    return c["v"] % 2 == 0


def pipeline(lz, dr):
    return (lz.select(_pred, name="even")
            .project(["k", "v"])
            .join(dr.lazy(), on=("k",), strategy="shuffle")
            .groupby(("k",), {"v": ("sum", "count")}))


def main():
    nd = len(jax.devices())
    mesh = jax.make_mesh((nd,), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    left, right = make_data()
    batch_rows = N // N_BATCHES

    tmp = tempfile.mkdtemp(prefix="repro-bench-stream-")
    man = write_dataset(left, tmp, chunk_rows=batch_rows // 2)
    dr = DDF.from_numpy(right, ctx, capacity=2 * (-(-N_RIGHT // nd)))
    dl = DDF.from_numpy(left, ctx, capacity=2 * (-(-N // nd)))

    def mono():
        return pipeline(dl.lazy(), dr).collect()

    def stream_run(prefetch):
        lz = pipeline(stream.scan_dataset(man, ctx, batch_rows=batch_rows), dr)
        return lz.collect_stream(prefetch=prefetch)

    # correctness: streamed == monolithic, bit for bit
    ref = mono().to_numpy()
    got = stream_run(True).to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k

    t_mono = time_fn(lambda: mono().counts, repeat=3)
    t_serial = time_fn(lambda: stream_run(False).counts, repeat=3)
    t_overlap = time_fn(lambda: stream_run(True).counts, repeat=3)

    overlap_gain = t_serial / t_overlap
    emit("stream/monolithic_4op", t_mono, f"P={nd},rows={N}")
    emit("stream/serial_decode_4op", t_serial,
         f"P={nd},batches={N_BATCHES},vs_mono={t_mono / t_serial:.3f}")
    emit("stream/overlap_decode_4op", t_overlap,
         f"P={nd},batches={N_BATCHES},overlap_gain={overlap_gain:.3f}")

    record = {
        "P": nd,
        "rows_on_disk": N,
        "rows_right_in_memory": N_RIGHT,
        "batch_rows": batch_rows,
        "n_batches": N_BATCHES,
        "pipeline": "select -> project -> join -> groupby",
        "t_monolithic_s": t_mono,
        "t_stream_serial_s": t_serial,
        "t_stream_overlap_s": t_overlap,
        "overlap_gain_over_serial": overlap_gain,
        "stream_overhead_vs_monolithic": t_overlap / t_mono,
        "bit_identical_to_monolithic": True,
    }
    out_path = os.path.join(os.path.dirname(__file__), "BENCH_STREAM.json")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    assert overlap_gain > 1.0, (
        f"decode/compute overlap gain {overlap_gain:.3f}x did not beat "
        "serial streaming")
    print(f"overlap gain over serial streaming: {overlap_gain:.2f}x; "
          f"streamed vs monolithic-when-it-fits: "
          f"{t_overlap / t_mono:.2f}x wall", flush=True)


if __name__ == "__main__":
    main()
