"""Paper Table 4: core local operator costs + complexity fits.

Times single-partition sort / join / groupby / unique / select across sizes
on one device and fits the per-row constant gamma used by the cost model
(CostParams.gamma_s_per_row)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core.dataframe import from_numpy
from repro.core.local_ops import local_groupby, local_join, local_sort, local_unique, select
from repro.data.synthetic import uniform_table


def main():
    sizes = [20_000, 80_000, 320_000]
    gammas = []
    for n in sizes:
        data = uniform_table(n, cardinality=0.9, seed=1)
        t = from_numpy(data)
        t2 = from_numpy(uniform_table(n, cardinality=0.9, seed=2))

        f_sort = jax.jit(lambda t: local_sort(t, ["c0"]).columns["c0"])
        ts = time_fn(f_sort, t)
        emit(f"local/sort_n{n}", ts, f"n_log_n_const={ts / (n * math.log2(n)):.3e}")

        f_join = jax.jit(lambda a, b: local_join(a, b, ["c0"], capacity=4 * n)[0].nvalid)
        tj = time_fn(f_join, t, t2)
        emit(f"local/join_n{n}", tj, f"per_row={tj / n:.3e}")

        f_gb = jax.jit(lambda t: local_groupby(t, ["c0"], {"c1": ("sum",)}).nvalid)
        tg = time_fn(f_gb, t)
        emit(f"local/groupby_n{n}", tg, f"per_row={tg / n:.3e}")

        f_uq = jax.jit(lambda t: local_unique(t, ["c0"]).nvalid)
        tu = time_fn(f_uq, t)
        emit(f"local/unique_n{n}", tu, f"per_row={tu / n:.3e}")

        f_sel = jax.jit(lambda t: select(t, lambda c: c["c1"] > 0).nvalid)
        tsel = time_fn(f_sel, t)
        emit(f"local/select_n{n}", tsel, f"per_row={tsel / n:.3e}")
        gammas.append(tsel / n)

    emit("local/gamma_s_per_row", float(np.median(gammas)),
         f"CostParams calibration gamma={float(np.median(gammas)):.3e}s/row")


if __name__ == "__main__":
    main()
