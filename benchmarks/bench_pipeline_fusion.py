"""Eager vs lazy-optimized execution of a 4-op pipeline (ISSUE 2 tentpole).

Times ``select -> project -> join -> groupby`` over 8 host devices two ways:

- **eager**: today's per-op path — each method plans in isolation (blocking
  row-count syncs), jits one operator, and the groupby re-shuffles the join
  output it was already co-partitioned with;
- **lazy**: one logical plan through the optimizer — predicate/projection
  pushdown shrinks the shuffled bytes, the join->groupby shuffle is elided
  (co-partition reuse), the EP prefix fuses into the join stage, and the
  whole pipeline compiles into a single shard_map program.

A "lazy (plan-only)" variant runs the same plan with only the cost-model
planning pass (no rewrites) to separate whole-pipeline-compilation gains
from optimizer gains. Asserts the acceptance bar (>= 1.2x lazy-optimized
over eager, pushdown + elision visible in ``.explain()``) and writes
``BENCH_FUSION.json`` next to this file.
"""

import json
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import DDF, DDFContext

N = 120_000          # rows per side
KEYS = N // 2        # ~2 matches per key


def make_tables(ctx):
    rng = np.random.default_rng(0)
    nw = ctx.nworkers
    cap = 2 * (-(-N // nw))
    L = {"k": rng.integers(0, KEYS, N).astype(np.int32),
         "v": rng.integers(0, 1000, N).astype(np.int32),
         "junk_a": rng.integers(0, 5, N).astype(np.int32),
         "junk_b": rng.integers(0, 5, N).astype(np.int32)}
    R = {"k": rng.integers(0, KEYS, N).astype(np.int32),
         "w": rng.integers(0, 1000, N).astype(np.int32),
         "junk_c": rng.integers(0, 5, N).astype(np.int32),
         "junk_d": rng.integers(0, 5, N).astype(np.int32)}
    return (DDF.from_numpy(L, ctx, capacity=cap),
            DDF.from_numpy(R, ctx, capacity=cap))


def _pred(c):
    return c["v"] % 2 == 0


# Join strategy is pinned to "shuffle" in BOTH modes so the comparison is
# apples-to-apples (and the explain demo shows the shuffle-join -> elided
# groupby co-partition reuse); the cost model still picks num_chunks.

def eager_pipeline(dl, dr):
    s = dl.select(_pred, name="even")
    p = s.project(["k", "v"])
    j, _ = p.join(dr, on=("k",), strategy="shuffle")   # own jit per op
    g, _ = j.groupby(("k",), {"v": ("sum", "count")})  # planner sync + reshuffle
    return g


def lazy_pipeline(dl, dr, level="all"):
    lz = (dl.lazy().select(_pred, name="even")
          .project(["k", "v"])
          .join(dr.lazy(), on=("k",), strategy="shuffle")
          .groupby(("k",), {"v": ("sum", "count")}))
    return lz.collect(level=level)


def main():
    nd = len(jax.devices())
    mesh = jax.make_mesh((nd,), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    dl, dr = make_tables(ctx)

    # acceptance: pushdown below the join shuffle + join->groupby elision
    lz = (dl.lazy().select(_pred, name="even").project(["k", "v"])
          .join(dr.lazy(), on=("k",), strategy="shuffle")
          .groupby(("k",), {"v": ("sum", "count")}))
    explain = lz.explain()
    print(explain, flush=True)
    assert explain.index("JOIN") < explain.index("PROJECT"), "no pushdown below join"
    assert "elide_shuffle" in explain, "groupby shuffle not elided"
    assert explain.strip().endswith("shuffles: 1"), "more than one shuffle"

    # correctness: lazy == eager before timing anything
    ref = eager_pipeline(dl, dr).to_numpy()
    got = lazy_pipeline(dl, dr).to_numpy()
    for k in ref:
        assert np.array_equal(ref[k], got[k]), k

    t_eager = time_fn(lambda: eager_pipeline(dl, dr).counts, repeat=5)
    t_lazy = time_fn(lambda: lazy_pipeline(dl, dr).counts, repeat=5)
    t_plan_only = time_fn(lambda: lazy_pipeline(dl, dr, level="plan-only").counts,
                          repeat=5)

    speedup = t_eager / t_lazy
    emit("fusion/eager_4op", t_eager, f"P={nd}")
    emit("fusion/lazy_plan_only_4op", t_plan_only,
         f"P={nd},speedup={t_eager / t_plan_only:.3f}")
    emit("fusion/lazy_optimized_4op", t_lazy, f"P={nd},speedup={speedup:.3f}")

    record = {
        "P": nd,
        "rows_per_side": N,
        "pipeline": "select -> project -> join -> groupby",
        "t_eager_s": t_eager,
        "t_lazy_plan_only_s": t_plan_only,
        "t_lazy_optimized_s": t_lazy,
        "speedup_lazy_over_eager": speedup,
        "speedup_plan_only_over_eager": t_eager / t_plan_only,
        "explain": explain.splitlines(),
    }
    out_path = os.path.join(os.path.dirname(__file__), "BENCH_FUSION.json")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    assert speedup >= 1.2, f"lazy speedup {speedup:.2f}x below the 1.2x bar"
    print(f"lazy-optimized speedup over eager: {speedup:.2f}x "
          f"(plan-only: {t_eager / t_plan_only:.2f}x)", flush=True)


if __name__ == "__main__":
    main()
