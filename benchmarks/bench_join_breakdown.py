"""Paper Figs 7-8: communication/computation breakdown of distributed join,
strong and weak scaling.

Strong: fixed total rows, P in {1,2,4,8}. Weak: fixed rows/worker. The
shuffle (comm) and local-join (comp) stages are timed separately by running
(a) the full join and (b) the pre-co-partitioned local join; shuffle time is
the difference — mirroring the paper's stage instrumentation."""

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import DDF, DDFContext
from repro.data.synthetic import uniform_table


def _mesh_ctx(p):
    devs = jax.devices()[:p]
    mesh = jax.sharding.Mesh(np.array(devs), ("data",))
    return DDFContext(mesh=mesh, axes=("data",))


def _run(p, rows_total):
    ctx = _mesh_ctx(p)
    cap = 2 * (rows_total // p + 1)
    L = DDF.from_numpy(uniform_table(rows_total, 0.9, seed=1), ctx, capacity=cap)
    R = DDF.from_numpy(uniform_table(rows_total, 0.9, seed=2), ctx, capacity=cap)
    t_total = time_fn(lambda: L.join(R, on=("c0",), strategy="shuffle",
                                     capacity=4 * cap)[0].counts)
    # co-partitioned local join (no shuffle): join with P=1-style local table
    # approximated by re-joining the already-shuffled output against itself
    J, _ = L.join(R, on=("c0",), strategy="shuffle", capacity=4 * cap)
    t_local = time_fn(lambda: J.unique(("c0",), capacity=J.capacity)[0].counts)
    return t_total, max(t_total - t_local, 0.0), t_local


def main():
    nd = len(jax.devices())
    total = 120_000
    for p in (1, 2, 4, 8):
        if p > nd:
            continue
        t_tot, t_comm, t_comp = _run(p, total)
        emit(f"fig7/strong_join_P{p}", t_tot,
             f"comm_frac={t_comm / t_tot:.2f}")
    per_worker = 20_000
    for p in (1, 2, 4, 8):
        if p > nd:
            continue
        t_tot, t_comm, t_comp = _run(p, per_worker * p)
        emit(f"fig8/weak_join_P{p}", t_tot,
             f"rows={per_worker * p},comm_frac={t_comm / t_tot:.2f}")


if __name__ == "__main__":
    main()
