"""Paper Fig 12: our patterns vs state-of-the-art-style baselines.

Stand-ins for the systems the paper compares against (no Dask/Spark here):
- "serial-style"  — gather everything to worker 0, compute locally
  (the pandas-on-driver anti-pattern);
- "modin-style"   — broadcast-join ONLY (paper §5.3.7 notes Modin OOMs on
  same-order relations because of this);
- "cylon-style"   — our cost-model-selected pattern (shuffle-compute /
  combine-shuffle-reduce / sample-shuffle-compute).

Operators: join (shuffle-compute), groupby (combine-shuffle-reduce), sort
(sample-shuffle-compute) — the three the paper benchmarks."""

import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import DDF, DDFContext
from repro.data.synthetic import uniform_table


def main():
    nd = len(jax.devices())
    mesh = jax.make_mesh((nd,), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    n = 100_000
    cap = 2 * (n // nd + 1)
    L = DDF.from_numpy(uniform_table(n, 0.9, seed=1), ctx, capacity=cap)
    R = DDF.from_numpy(uniform_table(n, 0.9, seed=2), ctx, capacity=cap)

    # ---- join ----
    t = time_fn(lambda: L.join(R, on=("c0",), strategy="shuffle",
                               capacity=4 * cap)[0].counts)
    emit("fig12/join_cylon_style", t, f"P={nd}")
    t = time_fn(lambda: L.join(R, on=("c0",), strategy="broadcast",
                               capacity=4 * cap)[0].counts)
    emit("fig12/join_modin_style", t, "broadcast-only (OOM-prone at scale)")
    ln, rn = L.to_numpy(), R.to_numpy()  # gather-to-driver

    def serial_join():
        import collections
        idx = collections.defaultdict(list)
        for i, k in enumerate(rn["c0"]):
            idx[k].append(i)
        return sum(len(idx.get(k, ())) for k in ln["c0"])

    import time as _t
    t0 = _t.perf_counter()
    serial_join()
    emit("fig12/join_serial_style", _t.perf_counter() - t0, "driver-local python")

    # ---- groupby ----
    t = time_fn(lambda: L.groupby(("c0",), {"c1": ("sum",)}, pre_combine=True)[0].counts)
    emit("fig12/groupby_cylon_style", t, "combine-shuffle-reduce")
    t = time_fn(lambda: L.groupby(("c0",), {"c1": ("sum",)}, pre_combine=False)[0].counts)
    emit("fig12/groupby_shuffle_only", t, "no combine (C=0.9 worst case)")

    # ---- sort ----
    t = time_fn(lambda: L.sort_values("c1")[0].counts)
    emit("fig12/sort_cylon_style", t, "sample-shuffle-compute")
    t0 = _t.perf_counter()
    np.sort(ln["c1"])
    emit("fig12/sort_serial_style", _t.perf_counter() - t0, "driver numpy")


if __name__ == "__main__":
    main()
