"""Statistics subsystem benchmark (ISSUE 9).

Two claims, both with bit-identity asserted:

- **Chunk skipping**: a selective scan over a dataset whose predicate
  column correlates with position (sorted ingest — the common
  time/id-ordered case) decodes measurably fewer chunks when the manifest
  carries per-chunk sketches, with output identical to the
  decode-everything run on the stats-stripped manifest.
- **Adaptive re-planning**: on a skewed-key streaming groupby (uniform
  keys early, one hot key late — the static quota is derived from the
  early shape), the cost model's ``shuffle_quota`` mean-abs-rel-err is
  strictly lower with ``adaptive=True`` than without, and the corrected
  stream's output is bit-identical to the static one.

Writes ``BENCH_STATS.json`` next to this file.
"""

import json
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks._util import emit
from repro import stream
from repro.core import DDFContext
from repro.data.dataset import write_dataset
from repro.expr import col
from repro.obs import model_check, trace

N_SCAN = 512_000
CHUNKS = 64
N_GB = 12_000
REPEAT = 5


def _canon(host):
    order = np.lexsort(tuple(host[k] for k in sorted(host)))
    return {k: v[order] for k, v in host.items()}


def _collect_timed(lz, **opts):
    t0 = time.perf_counter()
    out = lz.collect_stream(**opts).to_numpy()
    return out, time.perf_counter() - t0, lz.last_info


def bench_chunk_skip(ctx, root):
    rng = np.random.default_rng(0)
    data = {"ts": np.arange(N_SCAN, dtype=np.int32),  # sorted ingest column
            "v": rng.integers(0, 1000, N_SCAN).astype(np.int32)}
    man = write_dataset(data, os.path.join(root, "scan"),
                        chunk_rows=N_SCAN // CHUNKS)
    pred = col("ts") >= int(N_SCAN * 0.9)  # last ~10% of rows

    def run(manifest):
        lz = stream.scan_dataset(manifest, ctx, batch_rows=N_SCAN // 8,
                                 predicate=pred)
        return _collect_timed(lz)

    run(man)  # warm compile caches before timing
    ts_skip, ts_full = [], []
    for _ in range(REPEAT):
        out_s, t, info_s = run(man)
        ts_skip.append(t)
        out_f, t, info_f = run(dataclasses.replace(man, stats=None))
        ts_full.append(t)
    assert info_s["chunks_skipped"] > 0, "sketches must prune chunks"
    assert info_f["chunks_skipped"] == 0
    assert set(out_s) == set(out_f)
    for c in out_s:  # bit-identity: skipping never changes the answer
        assert np.array_equal(out_s[c], out_f[c]), c
    t_skip, t_full = float(np.median(ts_skip)), float(np.median(ts_full))
    emit("stats_scan_skip", t_skip,
         f"decoded {info_s['chunks_decoded']}/{CHUNKS} chunks")
    emit("stats_scan_full_decode", t_full,
         f"decoded {info_f['chunks_decoded']}/{CHUNKS} chunks")
    emit("stats_scan_skip_speedup", t_full - t_skip,
         f"x{t_full / max(t_skip, 1e-9):.2f}")
    return {
        "chunks_total": CHUNKS,
        "chunks_decoded_with_stats": int(info_s["chunks_decoded"]),
        "chunks_skipped": int(info_s["chunks_skipped"]),
        "seconds_with_stats": t_skip,
        "seconds_full_decode": t_full,
        "speedup": t_full / max(t_skip, 1e-9),
        "bit_identical": True,
    }


def bench_adaptive_quota(ctx, root):
    rng = np.random.default_rng(1)
    k = np.concatenate([rng.integers(0, 300, N_GB // 2),
                        np.full(N_GB - N_GB // 2, 7)]).astype(np.int64)
    v = rng.integers(0, 100, N_GB).astype(np.int64)
    man = write_dataset({"k": k, "v": v}, os.path.join(root, "skew"),
                        chunk_rows=500)

    def run(adaptive):
        lz = stream.scan_dataset(man, ctx, batch_rows=750) \
            .groupby(("k",), {"v": ("sum", "count")})
        since = model_check.mark()
        trace.enable()
        try:
            out = lz.collect_stream(adaptive=adaptive).to_numpy()
        finally:
            trace.disable()
        report = model_check.model_report(model_check.records(since))
        return _canon(out), report["shuffle_quota"], lz.last_info

    out_static, q_static, _ = run(adaptive=False)
    out_adapt, q_adapt, info = run(adaptive=True)
    for c in out_static:  # adaptation is result-invariant
        assert np.array_equal(out_static[c], out_adapt[c]), c
    assert info.get("replans", 0) >= 1, "skew must trigger a re-plan"
    err_s = q_static["mean_abs_rel_err"]
    err_a = q_adapt["mean_abs_rel_err"]
    assert err_a < err_s, (
        f"adaptive quota error {err_a:.3f} must beat static {err_s:.3f}")
    emit("stats_quota_err_static", err_s, f"{q_static['count']} samples")
    emit("stats_quota_err_adaptive", err_a,
         f"{info['replans']} replan(s); {q_adapt['count']} samples")
    return {
        "quota_mean_abs_rel_err_static": err_s,
        "quota_mean_abs_rel_err_adaptive": err_a,
        "replans": int(info["replans"]),
        "bit_identical": True,
    }


def main():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    results = {}
    with tempfile.TemporaryDirectory() as root:
        results["chunk_skip"] = bench_chunk_skip(ctx, root)
        results["adaptive_quota"] = bench_adaptive_quota(ctx, root)
    out_path = os.path.join(os.path.dirname(__file__), "BENCH_STATS.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2)
    emit("stats_total", 0.0, f"wrote {os.path.basename(out_path)}")


if __name__ == "__main__":
    main()
