"""Concurrent query service benchmark (ISSUE 7 tentpole).

Runs a batch of 8 mixed queries (streaming scans + scan-free lazy
pipelines) two ways on one 8-host-device mesh:

- **serial**: each query's ``collect``/``collect_stream`` back to back —
  the only option before ``repro.service``;
- **concurrent**: all 8 submitted to one ``QueryService`` and interleaved
  at morsel granularity under the ``fair`` policy.

Records batch throughput (queries/s, concurrent must be >= serial — one
driver thread, so the win comes from overlapping host decode/result
handling with device work, not from device parallelism), per-query
latency p50/p95, the fairness spread (max/min measured device seconds
across the equal-weight streaming queries), and the shared plan/compiled-
op cache hit rates across queries sharing a plan shape (must be > 0).
Asserts concurrent results are bit-identical to serial; writes
``BENCH_SERVICE.json`` next to this file.
"""

import json
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from benchmarks._util import emit
from repro import stream
from repro.core import DDF, DDFContext
from repro.data.dataset import write_dataset
from repro.service import QueryService

N_DISK = 160_000     # per streaming query, on disk
N_MEM = 40_000       # per lazy query, in memory
KEYS = 10_000
N_BATCHES = 8
N_STREAM = 4         # 4 streaming + 4 lazy = 8 concurrent queries
N_LAZY = 4


def make_queries(ctx, man, dl, dr):
    aggs = {"v": ("sum", "count")}
    # aggregating the wide columns defeats projection pushdown on purpose:
    # every streaming morsel decodes the full row width on the host
    stream_aggs = {"v": ("sum", "count"), "j0": ("sum",), "j1": ("sum",),
                   "j2": ("sum",), "j3": ("sum",)}
    batch_rows = N_DISK // N_BATCHES
    qs = []
    for _ in range(N_STREAM):
        qs.append(("stream",
                   lambda: stream.scan_dataset(man, ctx, batch_rows=batch_rows)
                   .groupby(("k",), stream_aggs)))
    for _ in range(N_LAZY):
        qs.append(("lazy",
                   lambda: dl.lazy().join(dr.lazy(), on=("k",),
                                          strategy="shuffle")
                   .groupby(("k",), aggs)))
    return qs


def run_serial(kinds_queries):
    outs, lat = [], []
    import time
    for kind, mk in kinds_queries:
        t0 = time.perf_counter()
        q = mk()
        out = stream.collect(q)[0] if kind == "stream" else q.collect()
        jax.block_until_ready(out.counts)
        outs.append(out)
        lat.append(time.perf_counter() - t0)
    return outs, lat


def run_concurrent(kinds_queries):
    import time
    with QueryService(policy="fair", max_running=8) as svc:
        t0 = time.perf_counter()
        handles = [svc.submit(mk()) for _, mk in kinds_queries]
        outs = [h.result(timeout=600) for h in handles]
        for out in outs:
            jax.block_until_ready(out.counts)
        wall = time.perf_counter() - t0
        lat = [h.finished_at - h.submitted_at for h in handles]
        device_s = [h.device_s for h in handles
                    if getattr(h.query, "_scans", None)]
        caches = svc.stats()["caches"]
    return outs, lat, wall, device_s, caches


def main():
    nd = len(jax.devices())
    mesh = jax.make_mesh((nd,), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))
    rng = np.random.default_rng(0)

    # extra columns make host decode a real fraction of each morsel, so the
    # concurrent win (all queries' prefetch decodes overlap device work)
    # is visible and not noise
    disk = {"k": rng.integers(0, KEYS, N_DISK).astype(np.int32),
            "v": rng.integers(0, 1000, N_DISK).astype(np.int32),
            "j0": rng.integers(0, 5, N_DISK).astype(np.int32),
            "j1": rng.integers(0, 5, N_DISK).astype(np.int32),
            "j2": rng.random(N_DISK).astype(np.float32),
            "j3": rng.random(N_DISK).astype(np.float32)}
    mem = {"k": rng.integers(0, KEYS, N_MEM).astype(np.int32),
           "v": rng.integers(0, 1000, N_MEM).astype(np.int32)}
    right = {"k": rng.integers(0, KEYS, N_MEM // 4).astype(np.int32),
             "w": rng.integers(0, 50, N_MEM // 4).astype(np.int32)}

    tmp = tempfile.mkdtemp(prefix="repro-bench-service-")
    man = write_dataset(disk, tmp, chunk_rows=(N_DISK // N_BATCHES) // 2)
    dl = DDF.from_numpy(mem, ctx, capacity=2 * (-(-N_MEM // nd)))
    dr = DDF.from_numpy(right, ctx, capacity=2 * (-(-(N_MEM // 4) // nd)))

    queries = make_queries(ctx, man, dl, dr)

    # warm both code paths once (compiles amortize across the real runs)
    run_serial(queries[:1] + queries[N_STREAM:N_STREAM + 1])

    import time
    t0 = time.perf_counter()
    serial_outs, serial_lat = run_serial(queries)
    serial_wall = time.perf_counter() - t0

    conc_outs, conc_lat, conc_wall, device_s, caches = run_concurrent(queries)

    # correctness: concurrent == serial, bit for bit, per query
    for i, (ref, got) in enumerate(zip(serial_outs, conc_outs)):
        rn, gn = ref.to_numpy(), got.to_numpy()
        for k in rn:
            assert np.array_equal(rn[k], gn[k]), f"query {i} column {k}"

    thr_serial = len(queries) / serial_wall
    thr_conc = len(queries) / conc_wall
    p50 = float(np.percentile(conc_lat, 50))
    p95 = float(np.percentile(conc_lat, 95))
    p50_serial = float(np.percentile(serial_lat, 50))
    fairness = (max(device_s) / max(min(device_s), 1e-9)) if device_s else 1.0
    op_w = caches["op"]["window"]
    plan_w = caches["plan"]["window"]
    op_rate = op_w["hits"] / max(op_w["hits"] + op_w["misses"], 1)
    plan_rate = plan_w["hits"] / max(plan_w["hits"] + plan_w["misses"], 1)

    emit("service/serial_batch", serial_wall,
         f"P={nd},queries={len(queries)},thr={thr_serial:.2f}q/s")
    emit("service/concurrent_batch", conc_wall,
         f"P={nd},queries={len(queries)},thr={thr_conc:.2f}q/s,"
         f"speedup={serial_wall / conc_wall:.3f}")
    emit("service/latency_p50", p50, f"p95={p95 * 1e6:.1f}us")
    emit("service/fairness_spread", 0.0,
         f"max_over_min_device_s={fairness:.3f}")
    emit("service/cache_hit_rates", 0.0,
         f"op={op_rate:.3f},plan={plan_rate:.3f}")

    record = {
        "P": nd,
        "queries": len(queries),
        "mix": f"{N_STREAM} streaming + {N_LAZY} lazy",
        "rows_on_disk_per_stream_query": N_DISK,
        "rows_in_memory_per_lazy_query": N_MEM,
        "serial_wall_s": serial_wall,
        "concurrent_wall_s": conc_wall,
        "throughput_serial_qps": thr_serial,
        "throughput_concurrent_qps": thr_conc,
        "concurrent_speedup": serial_wall / conc_wall,
        "latency_serial_p50_s": p50_serial,
        "latency_concurrent_p50_s": p50,
        "latency_concurrent_p95_s": p95,
        "fairness_spread_device_s": fairness,
        "op_cache_hit_rate": op_rate,
        "plan_cache_hit_rate": plan_rate,
        "bit_identical_to_serial": True,
    }
    out_path = os.path.join(os.path.dirname(__file__), "BENCH_SERVICE.json")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    assert op_rate > 0.0, "no compiled-op cache reuse across queries"
    assert plan_rate > 0.0, "no plan cache reuse across queries"
    assert thr_conc >= 0.9 * thr_serial, (
        f"concurrent throughput {thr_conc:.2f} q/s fell more than 10% below "
        f"serial {thr_serial:.2f} q/s")
    print(f"concurrent {thr_conc:.2f} q/s vs serial {thr_serial:.2f} q/s "
          f"({serial_wall / conc_wall:.2f}x); p50 {p50 * 1e3:.0f}ms "
          f"p95 {p95 * 1e3:.0f}ms; fairness spread {fairness:.2f}; "
          f"cache hit rates op={op_rate:.2f} plan={plan_rate:.2f}",
          flush=True)


if __name__ == "__main__":
    main()
