"""Emit the EXPERIMENTS.md §Roofline table from dry-run JSON records."""

import glob
import json
import os
import sys

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(mesh: str | None = "16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fmt_row(r):
    if r["status"] == "skipped":
        return f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | {r['reason'][:40]} |"
    if r["status"] != "ok":
        return f"| {r['arch']} | {r['shape']} | — | — | — | — | ERROR | {r['error'][:40]} |"
    ro = r["roofline"]
    mem = r["memory"]["bytes_per_device"] / 1e9
    return ("| {arch} | {shape} | {tc:.2e} | {tm:.2e} | {tl:.2e} | {dom} | "
            "{frac:.3f} | {useful:.2f} | {mem:.1f} |").format(
        arch=r["arch"], shape=r["shape"],
        tc=ro["t_compute_s"], tm=ro["t_memory_s"], tl=ro["t_collective_s"],
        dom=ro["dominant"], frac=ro["roofline_fraction"],
        useful=ro["useful_flops_ratio"], mem=mem)


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    recs = load_records(mesh)
    print(f"### Roofline table ({mesh} mesh, {len(recs)} cells)")
    print("| arch | shape | t_compute(s) | t_memory(s) | t_coll(s) | dominant "
          "| roofline_frac | useful_ratio | mem/dev GB |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(fmt_row(r))
    ok = [r for r in recs if r["status"] == "ok"]
    if ok:
        doms = {}
        for r in ok:
            doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
        print(f"\ndominant-term census: {doms}")


if __name__ == "__main__":
    main()
