"""Benchmark harness entry (task spec deliverable (d)).

One benchmark per paper table/figure; each runs in a subprocess so it can
set its own host-device count. Prints ``name,us_per_call,derived`` CSV.

  Table 3  -> bench_comm           (collective costs vs Hockney model)
  Table 4  -> bench_local_ops      (core local operator costs)
  Fig 7/8  -> bench_join_breakdown (join comm/comp, strong+weak scaling)
  Fig 10/11+Table 5 -> bench_scaling (Summit-style scaling + projection)
  Fig 12   -> bench_vs_naive       (patterns vs baseline strategies)
  ISSUE 1  -> bench_pipeline       (monolithic vs pipelined chunked shuffle)
  ISSUE 2  -> bench_pipeline_fusion (eager per-op vs lazy-optimized pipeline)
  ISSUE 3  -> bench_stream         (out-of-core streaming: overlap vs serial
                                    decode vs monolithic-when-it-fits)
  ISSUE 4  -> bench_expr           (expression-compiled select/derive vs the
                                    legacy callable path, eager + lazy)
  ISSUE 5  -> bench_kernels        (Pallas dataframe kernels vs jnp hot
                                    paths: timings, parity, dispatch audit)
  ISSUE 6  -> bench_recovery       (streaming checkpoint overhead at the
                                    default cadence + kill/resume latency)
  ISSUE 7  -> bench_service        (8 concurrent mixed queries through the
                                    query service vs serial: throughput,
                                    p50/p95 latency, fairness spread,
                                    shared-cache hit rates)
  ISSUE 8  -> bench_obs            (tracing overhead on the 4-op pipeline —
                                    must stay under 3% with bit-identical
                                    results — plus per-pattern cost-model
                                    error reports and the disabled-mode
                                    null-span cost)
  ISSUE 9  -> bench_stats          (per-chunk sketches: selective-scan
                                    chunk-skip speedup with bit-identical
                                    output, and shuffle-quota prediction
                                    error with vs without adaptive
                                    mid-stream re-planning on skewed keys)
  ISSUE 10 -> bench_types          (dict-encoded string keys: join/groupby
                                    vs a pre-coded int32 baseline, plus
                                    isolated vocab-unification/recode
                                    overhead)
"""

import os
import subprocess
import sys

BENCHES = [
    "benchmarks.bench_local_ops",
    "benchmarks.bench_comm",
    "benchmarks.bench_join_breakdown",
    "benchmarks.bench_scaling",
    "benchmarks.bench_vs_naive",
    "benchmarks.bench_pipeline",
    "benchmarks.bench_pipeline_fusion",
    "benchmarks.bench_stream",
    "benchmarks.bench_expr",
    "benchmarks.bench_kernels",
    "benchmarks.bench_recovery",
    "benchmarks.bench_service",
    "benchmarks.bench_obs",
    "benchmarks.bench_stats",
    "benchmarks.bench_types",
]


def main() -> None:
    print("name,us_per_call,derived")
    root = os.path.join(os.path.dirname(__file__), "..")
    failures = 0
    for mod in BENCHES:
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")])
        res = subprocess.run([sys.executable, "-m", mod], cwd=root,
                             capture_output=True, text=True, timeout=3600, env=env)
        sys.stdout.write(res.stdout)
        if res.returncode != 0:
            failures += 1
            print(f"{mod},0.0,FAILED rc={res.returncode}")
            sys.stderr.write(res.stderr[-2000:])
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
