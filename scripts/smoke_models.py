"""Instantiate every assigned arch at reduced config: one forward + one
decode step on CPU; assert shapes + finiteness."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_smoke_config
from repro.models import build_model


def batch_for(cfg, B=2, S=16):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    total = S
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
        total = S + cfg.n_patches
    if cfg.family == "encdec":
        batch["enc_frames"] = jnp.asarray(rng.normal(size=(B, cfg.enc_positions, cfg.d_model)), jnp.float32)
    return batch, total


def main():
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init_params(jax.random.key(0))
        n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))

        B, S = 2, 16
        batch, total = batch_for(cfg, B, S)
        h, aux = jax.jit(model.forward)(params, batch)
        assert h.shape == (B, total, cfg.d_model), (arch, h.shape)
        logits = model.unembed(params, h)
        assert logits.shape == (B, total, cfg.vocab_size)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), f"{arch}: non-finite logits"

        # decode
        state = model.init_decode_state(B, 32)
        if cfg.family == "encdec":
            state["enc_out"] = jnp.zeros((B, cfg.enc_positions, cfg.d_model), jnp.bfloat16)
        tok = {"token": batch["tokens"][:, :1]}
        dl, state2 = jax.jit(model.decode_step)(params, state, tok)
        assert dl.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(dl.astype(jnp.float32)).all()), f"{arch}: non-finite decode logits"
        assert int(state2["length"]) == 1
        print(f"{arch:28s} OK  params={n_params:,}  fwd={h.shape}  dec={dl.shape}")

    print("ALL MODEL SMOKE TESTS PASSED")


if __name__ == "__main__":
    main()
