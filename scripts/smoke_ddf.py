"""DDF engine smoke: runs on N host devices (set by env) and checks results
against numpy oracles. Usable directly and via subprocess from tests."""
import os
import sys

if "--devices" in sys.argv:
    n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import DDF, DDFContext


def main():
    devs = jax.devices()
    print(f"devices: {len(devs)}")
    mesh = jax.make_mesh((len(devs),), ("data",))
    ctx = DDFContext(mesh=mesh, axes=("data",))

    rng = np.random.default_rng(0)
    n = 1000
    # ~90% cardinality like the paper's experiments
    lkey = rng.integers(0, 900, size=n).astype(np.int32)
    lval = rng.integers(0, 1000, size=n).astype(np.int32)
    rkey = rng.integers(0, 900, size=n).astype(np.int32)
    rval = rng.integers(0, 1000, size=n).astype(np.int32)

    L = DDF.from_numpy({"k": lkey, "v": lval}, ctx, capacity=2 * n)
    R = DDF.from_numpy({"k": rkey, "w": rval}, ctx, capacity=2 * n)

    # --- join (shuffle-compute) ---
    J, info = L.join(R, on=("k",), strategy="shuffle", capacity=16 * n)
    got = J.to_numpy()
    # numpy oracle
    import collections
    ridx = collections.defaultdict(list)
    for i, k in enumerate(rkey):
        ridx[int(k)].append(i)
    exp = []
    for i, k in enumerate(lkey):
        for j in ridx.get(int(k), []):
            exp.append((int(k), int(lval[i]), int(rval[j])))
    got_set = sorted(zip(got["k"].tolist(), got["v"].tolist(), got["w"].tolist()))
    assert int(np.asarray(info["overflow_left"]).sum()) == 0, "left shuffle overflow"
    assert int(np.asarray(info["overflow_right"]).sum()) == 0
    assert int(np.asarray(info["overflow_join"]).sum()) == 0
    assert got_set == sorted(exp), f"join mismatch: {len(got_set)} vs {len(exp)}"
    print(f"join OK: {len(got_set)} rows")

    # --- broadcast join ---
    J2, _ = L.join(R, on=("k",), strategy="broadcast", capacity=16 * n)
    got2 = J2.to_numpy()
    got2_set = sorted(zip(got2["k"].tolist(), got2["v"].tolist(), got2["w"].tolist()))
    assert got2_set == sorted(exp), "broadcast join mismatch"
    print("broadcast join OK")

    # --- groupby (combine-shuffle-reduce) ---
    G, ginfo = L.groupby(("k",), {"v": ("sum", "count", "mean", "min", "max")}, pre_combine=True)
    gg = G.to_numpy()
    order = np.argsort(gg["k"])
    exp_sum = {}
    exp_cnt = collections.Counter()
    exp_min = {}
    exp_max = {}
    for k, v in zip(lkey, lval):
        k = int(k)
        exp_sum[k] = exp_sum.get(k, 0) + int(v)
        exp_cnt[k] += 1
        exp_min[k] = min(exp_min.get(k, 1 << 30), int(v))
        exp_max[k] = max(exp_max.get(k, -1), int(v))
    ks = sorted(exp_sum)
    assert sorted(gg["k"].tolist()) == ks, "groupby keys mismatch"
    m = dict(zip(gg["k"].tolist(), gg["v_sum"].tolist()))
    assert all(m[k] == exp_sum[k] for k in ks), "groupby sum mismatch"
    m = dict(zip(gg["k"].tolist(), gg["v_count"].tolist()))
    assert all(m[k] == exp_cnt[k] for k in ks)
    m = dict(zip(gg["k"].tolist(), gg["v_min"].tolist()))
    assert all(m[k] == exp_min[k] for k in ks)
    m = dict(zip(gg["k"].tolist(), gg["v_mean"].tolist()))
    assert all(abs(m[k] - exp_sum[k] / exp_cnt[k]) < 1e-4 for k in ks)
    print(f"groupby OK: {len(ks)} groups")

    # also the no-combine variant
    G2, _ = L.groupby(("k",), {"v": ("sum",)}, pre_combine=False)
    gg2 = G2.to_numpy()
    m = dict(zip(gg2["k"].tolist(), gg2["v_sum"].tolist()))
    assert all(m[k] == exp_sum[k] for k in ks)
    print("groupby (shuffle-compute variant) OK")

    # --- sort (sample-shuffle-compute) ---
    S, sinfo = L.sort_values("v")
    ss = S.to_numpy()
    assert int(np.asarray(sinfo["overflow_shuffle"]).sum()) == 0, "sort shuffle overflow"
    assert np.array_equal(np.sort(lval), ss["v"]), "global sort mismatch"
    print("sort OK")

    # --- unique / union / difference ---
    U, _ = L.unique(("k",))
    assert sorted(U.to_numpy()["k"].tolist()) == sorted(set(lkey.tolist()))
    print("unique OK")

    UN, _ = L.project(["k"]).union(R.project(["k"]), on=("k",))
    assert sorted(UN.to_numpy()["k"].tolist()) == sorted(set(lkey.tolist()) | set(rkey.tolist()))
    print("union OK")

    DF, _ = L.project(["k"]).difference(R.project(["k"]), on=("k",))
    assert sorted(DF.to_numpy()["k"].tolist()) == sorted(set(lkey.tolist()) - set(rkey.tolist()))
    print("difference OK")

    # --- column agg (globally reduce) ---
    assert int(L.agg("v", "sum")) == int(lval.sum())
    assert abs(float(L.agg("v", "mean")) - float(lval.mean())) < 1e-3
    assert int(L.agg("v", "min")) == int(lval.min())
    assert L.length() == n
    print("column agg OK")

    # --- rolling window (halo exchange) ---
    W, winfo = L.rolling_sum("v", window=5)
    ww = W.to_numpy()
    ref = np.convolve(lval.astype(np.float64), np.ones(5), mode="full")[4:len(lval)]
    wvalid = ww["window_valid"]
    vals = ww["v_rollsum"][wvalid]
    assert not np.asarray(winfo["halo_short"]).any(), "partition shorter than window"
    assert np.allclose(vals, ref), "rolling sum mismatch"
    print("rolling window OK")

    # --- select / map (embarrassingly parallel) ---
    SEL = L.select(lambda c: c["v"] > 500)
    assert sorted(SEL.to_numpy()["v"].tolist()) == sorted(lval[lval > 500].tolist())
    print("select OK")

    # --- rebalance / head ---
    RB, _ = SEL.rebalance()
    cnts = np.asarray(RB.counts)
    assert cnts.max() - cnts.min() <= 1, f"unbalanced: {cnts}"
    assert sorted(RB.to_numpy()["v"].tolist()) == sorted(lval[lval > 500].tolist())
    print("rebalance OK")

    H = S.head(10)
    assert np.array_equal(H.to_numpy()["v"], np.sort(lval)[:10])
    print("head OK")

    # --- Bruck shuffle == native shuffle (paper Table 3 algorithm) ---
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.dataframe import Table
    from repro.core.partition import hash_partition_ids

    nw = ctx.nworkers
    cap = L.capacity

    def _shuf(alg="native", num_chunks=1):
        def run(cols, counts):
            t = Table(dict(cols), counts.reshape(()))
            dest = hash_partition_ids(t, ("k",), nw)
            out, ov = ctx.comm().shuffle(t, dest, quota=cap, algorithm=alg,
                                         num_chunks=num_chunks)
            return dict(out.columns), out.nvalid.reshape(1), ov.reshape(1)
        from repro.compat import shard_map
        sm = shard_map(run, mesh=mesh,
                           in_specs=({"k": P("data"), "v": P("data")}, P("data")),
                           out_specs=P("data"), check_vma=False)
        return jax.jit(sm)(L.columns, L.counts)

    cn, nn, _ = _shuf("native")
    cb, nb, _ = _shuf("bruck")
    assert np.array_equal(np.asarray(nn), np.asarray(nb)), "bruck counts mismatch"
    # same multiset of rows per partition (order may differ across sources);
    # shuffle output capacity per shard is P*quota
    P_ = nw
    capg = nw * cap
    for w in range(P_):
        n1 = int(np.asarray(nn)[w])
        a = sorted(zip(np.asarray(cn["k"]).reshape(P_, capg)[w][:n1].tolist(),
                       np.asarray(cn["v"]).reshape(P_, capg)[w][:n1].tolist()))
        b = sorted(zip(np.asarray(cb["k"]).reshape(P_, capg)[w][:n1].tolist(),
                       np.asarray(cb["v"]).reshape(P_, capg)[w][:n1].tolist()))
        assert a == b, f"bruck rows mismatch on worker {w}"
    print("bruck shuffle OK (matches native all-to-all)")

    # --- pipelined chunked shuffle == monolithic shuffle (bit-exact) ---
    for K in (2, 3, 4):
        cp, np_, ovp = _shuf(num_chunks=K)
        assert np.array_equal(np.asarray(nn), np.asarray(np_)), f"K={K} counts mismatch"
        assert int(np.asarray(ovp).sum()) == 0, f"K={K} unexpected overflow"
        for name in ("k", "v"):
            assert np.array_equal(np.asarray(cn[name]), np.asarray(cp[name])), (
                f"K={K} pipelined shuffle not bit-exact on column {name}")
    print("pipelined shuffle OK (bit-exact vs monolithic, K=2..4)")

    # pipelined path through the operators: join/groupby/sort with K=3
    Jp, infop = L.join(R, on=("k",), strategy="shuffle", capacity=16 * n, num_chunks=3)
    gp = Jp.to_numpy()
    gp_set = sorted(zip(gp["k"].tolist(), gp["v"].tolist(), gp["w"].tolist()))
    assert gp_set == sorted(exp), "pipelined join mismatch"
    assert int(np.asarray(infop["overflow_left"]).sum()) == 0
    Gp, _ = L.groupby(("k",), {"v": ("sum",)}, pre_combine=True, num_chunks=3)
    ggp = Gp.to_numpy()
    mp = dict(zip(ggp["k"].tolist(), ggp["v_sum"].tolist()))
    assert all(mp[k] == exp_sum[k] for k in ks), "pipelined groupby mismatch"
    Sp, _ = L.sort_values("v", num_chunks=3)
    assert np.array_equal(Sp.to_numpy()["v"], np.sort(lval)), "pipelined sort mismatch"
    print("pipelined operators OK (join/groupby/sort, K=3)")

    # --- lazy plan layer (ISSUE 2): whole-pipeline compile, bit-exact ---
    lz = (L.lazy().select(lambda c: c["v"] > 500, name="vbig")
          .join(R.lazy(), on=("k",), strategy="shuffle", capacity=16 * n)
          .groupby(("k",), {"v": ("sum", "count")}))
    ex = lz.explain()
    assert "elide_shuffle" in ex and ex.strip().endswith("shuffles: 1"), ex
    lzout = lz.to_numpy()
    ESel = L.select(lambda c: c["v"] > 500, name="vbig")
    EJ, _ = ESel.join(R, on=("k",), strategy="shuffle", capacity=16 * n)
    EG, _ = EJ.groupby(("k",), {"v": ("sum", "count")})
    eout = EG.to_numpy()
    for name in eout:
        assert np.array_equal(eout[name], lzout[name]), f"lazy mismatch: {name}"
    assert all(int(np.asarray(v).sum()) == 0 for v in lz.last_info.values())
    print("lazy plan OK (pushdown+elision, bit-exact vs eager)")

    # --- expression API (ISSUE 4): expr forms == callable forms, bit-exact ---
    from repro.expr import col
    XSel = L.select(col("v") > 500)
    assert np.array_equal(XSel.to_numpy()["v"], SEL.to_numpy()["v"])
    XW = L.with_column("d", col("v") * 2 + col("k"))
    host = L.to_numpy()
    assert np.array_equal(XW.to_numpy()["d"], host["v"] * 2 + host["k"])
    xlz = (L.lazy().select(col("v") > 500, name="vbig")
           .join(R.lazy(), on=("k",), strategy="shuffle", capacity=16 * n)
           .groupby(("k",), [col("v").sum(), col("v").count()]))
    xex = xlz.explain()
    assert "SELECT" in xex or "select[(v > 500)]" in xex, xex
    xout = xlz.to_numpy()
    for name in eout:
        assert np.array_equal(eout[name], xout[name]), f"expr mismatch: {name}"
    print("expression API OK (select/with_column/agg specs, bit-exact)")

    print("ALL DDF SMOKE TESTS PASSED")


if __name__ == "__main__":
    main()
