#!/usr/bin/env python
"""Backfill per-chunk statistics sketches into existing dataset manifests.

Datasets written before the statistics subsystem (ISSUE 9) — or written
with ``DatasetWriter(..., stats=False)``, e.g. resumed spill writers —
carry no per-chunk sketches, so scans over them cannot skip chunks or
estimate selectivities. This script recomputes the sketches by decoding
each chunk once and atomically rewrites ``manifest.json`` in place
(tmp-file + ``os.replace``; a crash mid-backfill leaves the old manifest
intact). Chunk ``.npz`` payloads are never touched, and the stats field
rides outside cache/checkpoint identity, so backfilling is always safe.

Usage::

    python scripts/backfill_stats.py DATASET_DIR [DATASET_DIR ...]
        [--k 128] [--force]

``--k`` sets the KMV sketch size (distinct-count accuracy ~ 1/sqrt(k));
``--force`` recomputes even when the manifest already has sketches
(e.g. to change ``k``).
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Backfill per-chunk sketches into dataset manifests")
    ap.add_argument("directories", nargs="+", metavar="DATASET_DIR",
                    help="dataset directories (each containing manifest.json)")
    ap.add_argument("--k", type=int, default=None,
                    help="KMV sketch size (default: repro.stats.DEFAULT_KMV_K)")
    ap.add_argument("--force", action="store_true",
                    help="recompute even if sketches already exist")
    args = ap.parse_args(argv)

    from repro.stats import DEFAULT_KMV_K, backfill_stats

    k = args.k if args.k is not None else DEFAULT_KMV_K
    status = 0
    for directory in args.directories:
        try:
            man = backfill_stats(directory, k=k, force=args.force)
        except (FileNotFoundError, ValueError) as e:
            print(f"{directory}: ERROR: {e}", file=sys.stderr)
            status = 1
            continue
        if man.stats is None:
            print(f"{directory}: no chunks to sketch (empty dataset)")
        else:
            print(f"{directory}: {len(man.stats)} chunk sketch(es) "
                  f"(k={man.stats_k}, {man.num_rows} rows)")
    return status


if __name__ == "__main__":
    raise SystemExit(main())
