"""Documentation lint (ISSUE 1-4 satellite CI check).

Fails (exit 1) if:
  1. any symbol exported via ``__all__`` from a module under
     ``repro.core`` (including ``repro.core.comm``), the lazy-plan
     package ``repro.plan``, the streaming engine ``repro.stream``, the
     chunked dataset layer ``repro.data.dataset``, or the expression API
     ``repro.expr`` lacks a docstring, or
  2. ``docs/PATTERNS.md`` / ``docs/ARCHITECTURE.md`` is missing, or does not
     mention every pattern key in ``repro.core.patterns.PATTERNS``, or
  3. ``docs/LAZY_PLANS.md`` is missing, or does not mention every logical
     node type and rewrite pass exported by ``repro.plan``, or
  4. ``docs/STREAMING.md`` is missing, or does not mention every
     ``repro.stream`` export (plus the batch-sizing entry point
     ``choose_batch_rows``), or
  5. ``docs/EXPRESSIONS.md`` is missing, or does not mention every
     ``repro.expr`` export (plus the entry points ``with_column`` and
     ``alias``), or
  6. ``docs/KERNELS.md`` is missing, or does not mention every
     ``repro.kernels`` export (plus the cost-model entry point
     ``kernel_params`` and the env override ``REPRO_KERNEL_BACKEND``), or
  7. ``docs/FAULT_TOLERANCE.md`` is missing, or does not mention every
     ``repro.testing`` export, the stream checkpoint/recovery API
     (``StreamCheckpoint``, ``RetryPolicy``, ``classify_error``, ...),
     every registered fault site, and the runner's checkpoint knobs
     (``checkpoint_dir`` / ``checkpoint_every`` / ``resume``), or
  8. ``docs/SERVICE.md`` is missing, or does not mention every
     ``repro.service`` export, lifecycle state, scheduling policy, and
     service knob (``max_running`` / ``memory_budget_bytes`` / ...), or
  9. ``docs/OBSERVABILITY.md`` is missing, or does not mention every
     ``repro.obs`` export, the engine's metric and span names, and the
     tracing/profiling knobs (``REPRO_TRACE`` / ``profile=True`` / ...), or
  10. ``docs/STATISTICS.md`` is missing, or does not mention every
     ``repro.stats`` export, the writer/stream statistics knobs
     (``stats_k`` / ``adaptive`` / ``replan_every``), and the
     cost-model adaptation constants (``ADAPTIVE_*``), or
  11. ``docs/TYPES.md`` is missing, or does not mention every
     ``repro.core.vocab`` export, the ``Recode`` plan node, the typed
     ``DatasetSchemaError``, and the vocab unification surface.

Run:  PYTHONPATH=src python scripts/check_docs.py
Wired into the test suite via tests/test_docs_lint.py.
"""

import importlib
import inspect
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

CORE_MODULES = [
    "repro.core.api",
    "repro.core.cost_model",
    "repro.core.dataframe",
    "repro.core.local_ops",
    "repro.core.operators",
    "repro.core.partition",
    "repro.core.patterns",
    "repro.core.comm.channels",
    "repro.core.comm.collectives",
    "repro.core.comm.communicator",
    # lazy logical-plan package (ISSUE 2): every export needs a docstring
    "repro.plan",
    "repro.plan.logical",
    "repro.plan.optimizer",
    "repro.plan.executor",
    "repro.plan.frame",
    # out-of-core streaming engine + dataset format (ISSUE 3)
    "repro.stream",
    "repro.stream.scan",
    "repro.stream.runner",
    "repro.data.dataset",
    # fault tolerance: checkpoint/resume + retry + fault injection (ISSUE 6)
    "repro.stream.checkpoint",
    "repro.stream.recovery",
    "repro.testing",
    "repro.testing.faults",
    # columnar expression API (ISSUE 4)
    "repro.expr",
    "repro.expr.tree",
    "repro.expr.aggs",
    # Pallas kernel layer + dispatch registry (ISSUE 5)
    "repro.kernels",
    "repro.kernels.ops",
    "repro.kernels.ref",
    "repro.kernels.registry",
    # concurrent query service (ISSUE 7)
    "repro.service",
    "repro.service.session",
    "repro.service.scheduler",
    "repro.service.admission",
    "repro.service.cache",
    # unified tracing + metrics + cost-model accounting (ISSUE 8)
    "repro.obs",
    "repro.obs.trace",
    "repro.obs.metrics",
    "repro.obs.model_check",
    # statistics: sketches, estimation, adaptive re-planning (ISSUE 9)
    "repro.stats",
    "repro.stats.sketch",
    "repro.stats.estimate",
    "repro.stats.adaptive",
    # dict-encoded string columns: vocabularies + unification (ISSUE 10)
    "repro.core.vocab",
]

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def missing_docstrings() -> list:
    """Return ["module.symbol", ...] for __all__ exports without docstrings."""
    missing = []
    for mod_name in CORE_MODULES:
        mod = importlib.import_module(mod_name)
        for sym in getattr(mod, "__all__", ()):
            obj = getattr(mod, sym, None)
            if obj is None:
                missing.append(f"{mod_name}.{sym} (missing symbol)")
                continue
            if not (inspect.isfunction(obj) or inspect.isclass(obj)):
                continue  # constants (dicts, profiles) document themselves
            if not inspect.getdoc(obj):
                missing.append(f"{mod_name}.{sym}")
    return missing


def missing_pattern_docs() -> list:
    """Return problems with docs/ coverage of the pattern registry."""
    from repro.core.patterns import PATTERNS

    problems = []
    for doc in ("docs/PATTERNS.md", "docs/ARCHITECTURE.md"):
        path = os.path.join(REPO_ROOT, doc)
        if not os.path.exists(path):
            problems.append(f"{doc} is missing")
            continue
        text = open(path).read()
        for pattern in PATTERNS:
            if pattern not in text:
                problems.append(f"{doc} does not mention pattern '{pattern}'")
    return problems


def missing_doc_mentions(doc: str, symbols) -> list:
    """Generic coverage check: every symbol must appear in the doc file."""
    path = os.path.join(REPO_ROOT, doc)
    if not os.path.exists(path):
        return [f"{doc} is missing"]
    text = open(path).read()
    return [f"{doc} does not mention '{sym}'" for sym in symbols
            if sym not in text]


def missing_lazy_plan_docs() -> list:
    """Return problems with docs/LAZY_PLANS.md coverage of the plan layer."""
    from repro.plan import logical, optimizer

    node_types = [s for s in logical.__all__
                  if inspect.isclass(getattr(logical, s, None))
                  and issubclass(getattr(logical, s), logical.Node)]
    passes = [s for s in optimizer.__all__ if s.startswith(("pushdown", "plan_",
                                                            "elide", "fuse"))]
    return missing_doc_mentions("docs/LAZY_PLANS.md", node_types + passes)


def missing_streaming_docs() -> list:
    """Return problems with docs/STREAMING.md coverage of repro.stream."""
    import repro.stream as stream_pkg

    return missing_doc_mentions(
        "docs/STREAMING.md",
        list(stream_pkg.__all__) + ["choose_batch_rows", "to_batches",
                                    "collect_stream"])


def missing_fault_tolerance_docs() -> list:
    """Return problems with docs/FAULT_TOLERANCE.md coverage of the
    fault-tolerance surface: the testing harness exports, the stream
    checkpoint/recovery API, every registered fault site, and the runner's
    checkpoint knobs."""
    import repro.testing as testing_pkg
    from repro.testing.faults import FAULT_SITES

    symbols = (list(testing_pkg.__all__)
               + ["StreamCheckpoint", "RetryPolicy", "call_with_retry",
                  "classify_error", "RETRYABLE_EXCEPTIONS",
                  "checkpoint_dir", "checkpoint_every", "resume",
                  "max_retries", "REPRO_CHAOS_SEED"]
               + list(FAULT_SITES))
    return missing_doc_mentions("docs/FAULT_TOLERANCE.md", symbols)


def missing_expression_docs() -> list:
    """Return problems with docs/EXPRESSIONS.md coverage of repro.expr."""
    import repro.expr as expr_pkg

    return missing_doc_mentions(
        "docs/EXPRESSIONS.md",
        list(expr_pkg.__all__) + ["with_column", "alias"])


def missing_service_docs() -> list:
    """Return problems with docs/SERVICE.md coverage of repro.service:
    every package export, each lifecycle state, both scheduling policies,
    and the admission/stream knobs of ``QueryService.submit``."""
    import repro.service as service_pkg
    from repro.service import POLICIES, QueryState

    symbols = (list(service_pkg.__all__)
               + list(QueryState.ALL) + list(POLICIES)
               + ["submit", "cancel", "shutdown", "stats",
                  "memory_budget_bytes", "max_running", "max_backlog",
                  "weight", "quantum_s"])
    return missing_doc_mentions("docs/SERVICE.md", symbols)


def missing_kernel_docs() -> list:
    """Return problems with docs/KERNELS.md coverage of repro.kernels."""
    import repro.kernels as kernels_pkg

    return missing_doc_mentions(
        "docs/KERNELS.md",
        list(kernels_pkg.__all__) + ["kernel_params", "KernelParams",
                                     "REPRO_KERNEL_BACKEND",
                                     "segment_reduce_partials"])


def missing_obs_docs() -> list:
    """Return problems with docs/OBSERVABILITY.md coverage of repro.obs:
    every package export, the metric names the engine emits, the span
    names each layer records, and the tracing/profiling knobs."""
    import repro.obs as obs_pkg

    symbols = (list(obs_pkg.__all__)
               + ["REPRO_TRACE", "to_chrome_trace", "model_report",
                  "peak_working_set_bytes", "retries:", "checkpoints",
                  "kernels.dispatch", "stream.decode", "stream.device_op",
                  "stream.stage", "service.morsel", "service.query",
                  "profile=True", "analyze=True", "query_learn_key"])
    return missing_doc_mentions("docs/OBSERVABILITY.md", symbols)


def missing_stats_docs() -> list:
    """Return problems with docs/STATISTICS.md coverage of repro.stats:
    every package export, the writer/stream knobs, and the cost-model
    adaptation constants."""
    import repro.stats as stats_pkg

    symbols = (list(stats_pkg.__all__)
               + ["stats_k", "adaptive", "replan_every", "chunks_skipped",
                  "chunks_decoded", "replans", "partition_histogram",
                  "ADAPTIVE_REPLAN_EVERY", "ADAPTIVE_DRIFT",
                  "ADAPTIVE_QUOTA_SAFETY", "ADAPTIVE_CAPACITY_SAFETY",
                  "backfill_stats", "shuffle_quota"])
    return missing_doc_mentions("docs/STATISTICS.md", symbols)


def missing_types_docs() -> list:
    """Return problems with docs/TYPES.md coverage of the dict-encoded
    string column subsystem: every ``repro.core.vocab`` export, the
    unification/recode surface, and the typed ingestion error."""
    from repro.core import vocab as vocab_mod

    symbols = (list(vocab_mod.__all__)
               + ["Recode", "DatasetSchemaError", "vocab_map", "bind_vocabs",
                  "is_in", "decode", "recode_map", "merge", "'dict'"])
    return missing_doc_mentions("docs/TYPES.md", symbols)


def main() -> int:
    failures = missing_docstrings()
    if failures:
        print("Missing docstrings on exported symbols:")
        for f in failures:
            print(f"  - {f}")
    doc_failures = missing_pattern_docs()
    if doc_failures:
        print("Pattern documentation problems:")
        for f in doc_failures:
            print(f"  - {f}")
    lazy_failures = missing_lazy_plan_docs()
    if lazy_failures:
        print("Lazy-plan documentation problems:")
        for f in lazy_failures:
            print(f"  - {f}")
    stream_failures = missing_streaming_docs()
    if stream_failures:
        print("Streaming documentation problems:")
        for f in stream_failures:
            print(f"  - {f}")
    fault_failures = missing_fault_tolerance_docs()
    if fault_failures:
        print("Fault-tolerance documentation problems:")
        for f in fault_failures:
            print(f"  - {f}")
    expr_failures = missing_expression_docs()
    if expr_failures:
        print("Expression documentation problems:")
        for f in expr_failures:
            print(f"  - {f}")
    kernel_failures = missing_kernel_docs()
    if kernel_failures:
        print("Kernel documentation problems:")
        for f in kernel_failures:
            print(f"  - {f}")
    service_failures = missing_service_docs()
    if service_failures:
        print("Query-service documentation problems:")
        for f in service_failures:
            print(f"  - {f}")
    obs_failures = missing_obs_docs()
    if obs_failures:
        print("Observability documentation problems:")
        for f in obs_failures:
            print(f"  - {f}")
    stats_failures = missing_stats_docs()
    if stats_failures:
        print("Statistics documentation problems:")
        for f in stats_failures:
            print(f"  - {f}")
    types_failures = missing_types_docs()
    if types_failures:
        print("Types documentation problems:")
        for f in types_failures:
            print(f"  - {f}")
    if failures or doc_failures or lazy_failures or stream_failures \
            or fault_failures or expr_failures or kernel_failures \
            or service_failures or obs_failures or stats_failures \
            or types_failures:
        return 1
    print("check_docs: all exported core+plan+stream+expr+kernel+testing+"
          "service+obs+stats+vocab symbols documented; docs cover every "
          "pattern, node type, rewrite pass, streaming, fault-tolerance, "
          "expression, kernel, service, observability, statistics and "
          "string-type export")
    return 0


if __name__ == "__main__":
    sys.exit(main())
